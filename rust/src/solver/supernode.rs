//! Supernodal symbolic analysis: the assembly tree.
//!
//! Starting from the scalar symbolic phase ([`super::etree`]), this module
//! builds everything the multifrontal numeric phase
//! ([`super::supernodal`]) consumes:
//!
//! 1. **Postorder relabeling.** The elimination tree is postordered and
//!    the analysis works on `B = Q·A·Qᵀ` for that relabeling `Q`. A
//!    postorder is an *equivalent reordering*: fill and flops are
//!    unchanged, and the factor of `B` is exactly `Q·L·Qᵀ` — so the
//!    numeric phase can factor `B` and keep the permutation inside the
//!    returned factor (see `LdlFactor::post`).
//! 2. **Exact factor structure.** The full column pattern of `L_B`
//!    (`lp`/`li`), via the same row-subtree walk that computes column
//!    counts. The numeric phase scatters the dense panels back onto this
//!    exact pattern, which is what keeps `fill()` identical to the scalar
//!    path even when amalgamation pads panels with explicit zeros.
//! 3. **Fundamental supernodes.** Maximal runs of columns with nested
//!    patterns (`parent[j-1] == j`, `counts[j-1] == counts[j] + 1`) and a
//!    single-child chain (`first_descendants` equality).
//! 4. **Relaxed amalgamation.** A child supernode is merged into its
//!    assembly-tree parent when the padding this introduces stays under
//!    [`FactorConfig::relax_ratio`] — trading a few explicit zeros for
//!    larger dense panels (fewer, bigger BLAS-style calls).
//! 5. **The assembly tree + a parallel schedule.** Per-supernode flop
//!    estimates, subtree aggregates, and a split of the tree into
//!    independent subtree tasks plus a sequential "top" set.
//!
//! Like all of the solver's symbolic side, a [`SupernodalPlan`] is a
//! pure function of the pattern — build it once per `(pattern, ordering,
//! config)` and replay it against any values (the plan/execute split in
//! [`crate::solver::plan`] caches exactly this object, together with the
//! scalar symbolic and a value-refresh gather).

use std::sync::Arc;

use super::etree::{first_descendants, postorder, SymbolicCost, NONE};
use super::numeric::{self, Symbolic};
use crate::sparse::CsrMatrix;

/// Which numeric factorization [`super::solve_ordered`] runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FactorMode {
    /// Scalar up-looking LDLᵀ (`super::numeric`).
    Scalar,
    /// Supernodal multifrontal, sequential elimination.
    Supernodal,
    /// Supernodal multifrontal, independent subtrees across threads.
    SupernodalParallel,
}

/// Knobs for the supernodal factorization.
#[derive(Clone, Copy, Debug)]
pub struct FactorConfig {
    pub mode: FactorMode,
    /// Relaxed amalgamation: merge a child supernode into its parent when
    /// `padded_entries <= relax_ratio * exact_entries` for the merged
    /// panel. 0 disables amalgamation (fundamental supernodes only).
    pub relax_ratio: f64,
    /// Hard cap on supernode width (pivot columns per front).
    pub relax_max_width: usize,
    /// Block size for the dense panel kernels.
    pub panel_block: usize,
    /// Worker threads for `SupernodalParallel` (0 = auto).
    pub workers: usize,
    /// Below this many symbolic flops the parallel mode runs sequentially
    /// (thread spawn would dominate sub-millisecond factorizations; the
    /// numerics are identical either way).
    pub parallel_flop_min: f64,
}

impl Default for FactorConfig {
    fn default() -> Self {
        FactorConfig {
            mode: FactorMode::SupernodalParallel,
            relax_ratio: 0.2,
            relax_max_width: 64,
            panel_block: 32,
            workers: 0,
            parallel_flop_min: 5e6,
        }
    }
}

impl FactorConfig {
    /// 64-bit fingerprint over every knob, mixed into the
    /// [`crate::solver::plan_cache::PlanKey`]: two configs with different
    /// fingerprints may plan differently (mode selects the symbolic
    /// structure, `relax_*` shape the amalgamation), so they must not
    /// share a cached [`crate::solver::SymbolicFactorization`]. The
    /// purely-numeric knobs (`panel_block`, `workers`,
    /// `parallel_flop_min`) are folded in too — a redundant cache entry
    /// is cheaper than reasoning about which knobs are plan-neutral.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        let mut mix = |x: u64| {
            h ^= x;
            h = h.wrapping_mul(0x100000001b3).rotate_left(7);
        };
        mix(self.mode as u64);
        mix(self.relax_ratio.to_bits());
        mix(self.relax_max_width as u64);
        mix(self.panel_block as u64);
        mix(self.workers as u64);
        mix(self.parallel_flop_min.to_bits());
        h
    }
}

/// The assembly tree and everything needed to factor numerically.
#[derive(Clone, Debug)]
pub struct SupernodalPlan {
    pub n: usize,
    /// `post[k]` = original column sitting at postorder position `k`.
    /// `Arc`ed so every [`super::numeric::LdlFactor`] the plan produces
    /// shares it instead of copying O(n) per factorization.
    pub post: Arc<Vec<usize>>,
    /// `pnew[old]` = postorder position (inverse of `post`).
    pub pnew: Vec<usize>,
    /// Pattern of the postordered matrix `B = Q·A·Qᵀ` (CSR), plus the
    /// gather map `b_from[k]` = slot in `A.data` feeding `B`'s slot `k` —
    /// so each factorization only gathers values instead of re-permuting.
    pub b_indptr: Vec<usize>,
    pub b_indices: Vec<usize>,
    pub b_from: Vec<usize>,
    /// Symbolic cost (fill/flops/max_col) — identical to the scalar
    /// symbolic cost of `A` (a postorder is an equivalent reordering).
    pub cost: SymbolicCost,
    /// Supernode `s` owns postordered columns `first[s]..first[s+1]`.
    pub first: Vec<usize>,
    /// Boundary rows per supernode: postordered indices beyond the last
    /// pivot column, ascending.
    pub rows: Vec<Vec<usize>>,
    /// Assembly-tree parent supernode (`NONE` for roots).
    pub sparent: Vec<usize>,
    /// Assembly-tree children (ascending).
    pub children: Vec<Vec<usize>>,
    /// Exact off-diagonal structure of `L_B`: column pointers + row
    /// indices (ascending per column). `Arc`ed: the factor pattern is
    /// pattern-pure, so every `LdlFactor` produced from this plan shares
    /// these arrays instead of paying an O(nnz(L)) copy per request —
    /// the last structural copy the warm serving path used to make.
    pub lp: Arc<Vec<usize>>,
    pub li: Arc<Vec<usize>>,
    /// Dense panel multiply-adds per supernode (includes padding).
    pub snode_flops: Vec<f64>,
    /// `snode_flops` aggregated over each subtree.
    pub subtree_flops: Vec<f64>,
    /// Explicit zeros introduced by amalgamation (diagnostics).
    pub padded: u64,
    /// Dense elements (`ld²`) of the largest frontal matrix — sizes the
    /// per-worker arena's front buffer once per task.
    pub peak_front: usize,
    /// Per supernode: peak update-stack elements of a postorder walk of
    /// its subtree (the classical multifrontal stack bound, including the
    /// supernode's own update) — sizes a subtree task's arena stack.
    pub stack_peak: Vec<usize>,
}

impl SupernodalPlan {
    pub fn n_supernodes(&self) -> usize {
        self.first.len() - 1
    }

    /// Supernode owning postordered column `j`.
    pub fn snode_of(&self, j: usize) -> usize {
        // first[] is sorted; partition_point gives the count of
        // supernodes starting at or before j.
        self.first.partition_point(|&f| f <= j) - 1
    }

    pub fn total_flops(&self) -> f64 {
        self.snode_flops.iter().sum()
    }

    /// Update-stack peak (elements) of a whole-forest postorder walk.
    /// The stack drains completely between assembly-forest trees, so
    /// this is the max of [`Self::stack_peak`] over the roots — what the
    /// sequential driver sizes its arena with.
    pub fn serial_stack_peak(&self) -> usize {
        (0..self.n_supernodes())
            .filter(|&s| self.sparent[s] == NONE)
            .map(|s| self.stack_peak[s])
            .max()
            .unwrap_or(0)
    }

    /// Peak dense frontal-matrix footprint in bytes (`8 · peak_front`) —
    /// the per-worker arena sizing, reported by `bench_solver`.
    pub fn peak_front_bytes(&self) -> usize {
        8 * self.peak_front
    }
}

/// Build the assembly tree for the (already permuted) symmetric matrix.
pub fn plan(a: &CsrMatrix, cfg: &FactorConfig) -> SupernodalPlan {
    plan_with(a, &numeric::analyze(a), cfg)
}

/// Like [`plan`], reusing an existing scalar symbolic analysis of `a`.
/// The postordered tree and counts are O(n) *relabelings* of `sym`'s —
/// a postorder is a topological relabeling, so nothing symbolic needs
/// recomputing on the permuted pattern.
pub fn plan_with(a: &CsrMatrix, sym: &Symbolic, cfg: &FactorConfig) -> SupernodalPlan {
    plan_with_reuse(a, sym, cfg, None)
}

/// [`plan_with`] with structure sharing against a predecessor plan — the
/// incremental-replanning entry (`solver::plan`'s repair path hands the
/// drifted pattern's donor plan in). The analysis itself is always run
/// fresh (that is what makes repair bit-identical to from-scratch
/// planning by construction); what `prev` buys is **exact-equality
/// certificates** for the `Arc`ed structural arrays: when the freshly
/// computed postorder (or factor structure) equals the donor's, the
/// donor's `Arc` is adopted instead of allocating a new one, so every
/// factor the repaired plan family produces keeps sharing one postorder
/// and one `lp`/`li` across pattern drift that leaves them unchanged.
pub fn plan_with_reuse(
    a: &CsrMatrix,
    sym: &Symbolic,
    cfg: &FactorConfig,
    prev: Option<&SupernodalPlan>,
) -> SupernodalPlan {
    let n = a.nrows;
    assert_eq!(a.nrows, a.ncols, "plan needs a square matrix");

    // --- postorder relabeling
    let post = postorder(&sym.parent);
    let mut pnew = vec![0usize; n];
    for (k, &old) in post.iter().enumerate() {
        pnew[old] = k;
    }
    // etree and column counts of B, by relabeling (valid because the
    // relabeling is topological: ancestors keep larger labels)
    let mut parent = vec![NONE; n];
    let mut counts = vec![0usize; n];
    for v in 0..n {
        let pv = sym.parent[v];
        parent[pnew[v]] = if pv == NONE { NONE } else { pnew[pv] };
        counts[pnew[v]] = sym.counts[v];
    }
    let cost = sym.cost;

    // permuted pattern + value gather map (mirrors CsrMatrix::permute_sym,
    // but records each entry's source slot so the numeric phase can
    // refresh values in O(nnz) without sorting)
    let nnz = a.nnz();
    let mut counts_row = vec![0usize; n + 1];
    for r in 0..n {
        counts_row[pnew[r] + 1] += a.row_nnz(r);
    }
    for i in 0..n {
        counts_row[i + 1] += counts_row[i];
    }
    let b_indptr = counts_row.clone();
    let mut entries: Vec<(usize, usize)> = vec![(0, 0); nnz]; // (new col, src slot)
    let mut next = counts_row;
    for r in 0..n {
        let nr = pnew[r];
        for (k, &c) in a.row_indices(r).iter().enumerate() {
            entries[next[nr]] = (pnew[c], a.indptr[r] + k);
            next[nr] += 1;
        }
    }
    let mut b_indices = vec![0usize; nnz];
    let mut b_from = vec![0usize; nnz];
    for r in 0..n {
        let seg = &mut entries[b_indptr[r]..b_indptr[r + 1]];
        seg.sort_unstable_by_key(|&(c, _)| c);
        for (k, &(c, src)) in seg.iter().enumerate() {
            b_indices[b_indptr[r] + k] = c;
            b_from[b_indptr[r] + k] = src;
        }
    }


    // --- exact structure of L_B via the row-subtree walk
    let mut lp = vec![0usize; n + 1];
    for j in 0..n {
        lp[j + 1] = lp[j] + counts[j];
    }
    let mut li = vec![0usize; lp[n]];
    let mut cursor = lp.clone();
    let mut mark = vec![NONE; n];
    for i in 0..n {
        mark[i] = i;
        for &j in &b_indices[b_indptr[i]..b_indptr[i + 1]] {
            if j >= i {
                continue;
            }
            let mut k = j;
            while mark[k] != i {
                mark[k] = i;
                li[cursor[k]] = i;
                cursor[k] += 1;
                k = parent[k];
                debug_assert!(k != NONE, "row subtree escaped the forest");
            }
        }
    }

    // --- fundamental supernodes
    let fd = first_descendants(&parent);
    let mut starts: Vec<usize> = Vec::new();
    for j in 0..n {
        let glue = j > 0
            && parent[j - 1] == j
            && counts[j - 1] == counts[j] + 1
            && fd[j] == fd[j - 1];
        if !glue {
            starts.push(j);
        }
    }

    // supernode list as (begin, end, boundary rows)
    struct Snode {
        begin: usize,
        end: usize,
        rows: Vec<usize>,
    }
    let mut snodes: Vec<Snode> = Vec::with_capacity(starts.len());
    for (k, &a0) in starts.iter().enumerate() {
        let end = starts.get(k + 1).copied().unwrap_or(n);
        // nested patterns: boundary of the supernode = entries of the
        // first column's pattern at or beyond `end` (an ascending suffix)
        let pat = &li[lp[a0]..lp[a0 + 1]];
        let cut = pat.partition_point(|&r| r < end);
        snodes.push(Snode {
            begin: a0,
            end,
            rows: pat[cut..].to_vec(),
        });
    }

    // --- relaxed amalgamation (stack pass: merge child into the
    // immediately following supernode when it is the assembly parent and
    // the padding stays within budget)
    let mut merged: Vec<Snode> = Vec::with_capacity(snodes.len());
    let mut padded_total = 0u64;
    for s in snodes {
        let mut s = s;
        while let Some(c) = merged.last() {
            debug_assert_eq!(c.end, s.begin);
            let parent_ok = c
                .rows
                .first()
                .map_or(false, |&r| r >= s.begin && r < s.end);
            let width = s.end - c.begin;
            if !(parent_ok && cfg.relax_ratio > 0.0 && width <= cfg.relax_max_width) {
                break;
            }
            // union boundary: c's rows beyond s, merged with s's rows
            let c_cut = c.rows.partition_point(|&r| r < s.end);
            let mut union_rows =
                Vec::with_capacity(c.rows.len() - c_cut + s.rows.len());
            let (mut i, mut j) = (c_cut, 0usize);
            while i < c.rows.len() || j < s.rows.len() {
                let ri = c.rows.get(i).copied().unwrap_or(usize::MAX);
                let rj = s.rows.get(j).copied().unwrap_or(usize::MAX);
                if ri == rj {
                    union_rows.push(ri);
                    i += 1;
                    j += 1;
                } else if ri < rj {
                    union_rows.push(ri);
                    i += 1;
                } else {
                    union_rows.push(rj);
                    j += 1;
                }
            }
            // padding cost of the merged panel
            let m = union_rows.len() as u64;
            let mut dense = 0u64;
            let mut exact = 0u64;
            for col in c.begin..s.end {
                dense += (s.end - 1 - col) as u64 + m;
                exact += counts[col] as u64;
            }
            debug_assert!(dense >= exact);
            let padded = dense - exact;
            if padded as f64 > cfg.relax_ratio * exact.max(1) as f64 {
                break;
            }
            let c = merged.pop().unwrap();
            padded_total += padded;
            s = Snode {
                begin: c.begin,
                end: s.end,
                rows: union_rows,
            };
        }
        merged.push(s);
    }

    // --- assembly tree + flop estimates
    let ns = merged.len();
    let mut first = Vec::with_capacity(ns + 1);
    let mut rows = Vec::with_capacity(ns);
    for s in &merged {
        first.push(s.begin);
    }
    first.push(n);
    let mut snode_of_col = vec![0usize; n];
    for (k, s) in merged.iter().enumerate() {
        for c in s.begin..s.end {
            snode_of_col[c] = k;
        }
    }
    let mut sparent = vec![NONE; ns];
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); ns];
    let mut snode_flops = vec![0f64; ns];
    let mut peak_front = 0usize;
    let mut upd = vec![0usize; ns]; // update-matrix elements (m²)
    for (k, s) in merged.iter().enumerate() {
        if let Some(&r) = s.rows.first() {
            let p = snode_of_col[r];
            sparent[k] = p;
            children[p].push(k);
        }
        let m = s.rows.len();
        let ld = (s.end - s.begin) + m;
        peak_front = peak_front.max(ld * ld);
        upd[k] = m * m;
        for t in 0..(s.end - s.begin) {
            let h = (ld - 1 - t) as f64;
            snode_flops[k] += h * (h + 3.0) / 2.0;
        }
    }
    let mut subtree_flops = snode_flops.clone();
    for k in 0..ns {
        if sparent[k] != NONE {
            debug_assert!(sparent[k] > k, "assembly parent must follow child");
            subtree_flops[sparent[k]] += subtree_flops[k];
        }
    }
    // update-stack peak per subtree: while child c_i's subtree runs, the
    // updates of c_1..c_{i-1} sit beneath it; after the last child the
    // whole child set is resident, then popped and replaced by this
    // supernode's own update (children precede parents in index order,
    // so one ascending pass sees every child before its parent)
    let mut stack_peak = vec![0usize; ns];
    for k in 0..ns {
        let mut resident = 0usize;
        let mut pk = 0usize;
        for &c in &children[k] {
            pk = pk.max(resident + stack_peak[c]);
            resident += upd[c];
        }
        stack_peak[k] = pk.max(resident).max(upd[k]);
    }
    for s in merged {
        rows.push(s.rows);
    }

    // exact-equality certificates: adopt the donor's Arcs when the fresh
    // arrays match bit-for-bit, so a repaired plan family keeps sharing
    // one postorder / factor structure across drift that preserves them
    let post = match prev {
        Some(p) if *p.post == post => p.post.clone(),
        _ => Arc::new(post),
    };
    let (lp, li) = match prev {
        Some(p) if *p.lp == lp && *p.li == li => (p.lp.clone(), p.li.clone()),
        _ => (Arc::new(lp), Arc::new(li)),
    };

    SupernodalPlan {
        n,
        post,
        pnew,
        b_indptr,
        b_indices,
        b_from,
        cost,
        first,
        rows,
        sparent,
        children,
        lp,
        li,
        snode_flops,
        subtree_flops,
        padded: padded_total,
        peak_front,
        stack_peak,
    }
}

/// A split of the assembly tree into independent subtree tasks plus the
/// sequential top set that consumes their root updates.
#[derive(Clone, Debug)]
pub struct Schedule {
    /// Root supernode of each parallel task.
    pub task_roots: Vec<usize>,
    /// `task_of[s]` = task index, or `NONE` for top-set supernodes.
    pub task_of: Vec<usize>,
}

/// Split the tree into at least `target_tasks` independent subtrees (when
/// the tree allows it). Repeatedly expands the flop-heaviest subtree into
/// its children, moving the expanded node to the sequential top set,
/// until there are enough tasks or no subtree dominates.
pub fn schedule(plan: &SupernodalPlan, target_tasks: usize) -> Schedule {
    let ns = plan.n_supernodes();
    let total: f64 = plan.total_flops().max(1.0);
    let mut work: Vec<usize> = (0..ns).filter(|&s| plan.sparent[s] == NONE).collect();
    let mut in_top = vec![false; ns];
    for _ in 0..ns {
        if work.len() >= target_tasks {
            break;
        }
        // flop-heaviest candidate that still has children to expand
        let heavy = work
            .iter()
            .enumerate()
            .filter(|(_, &s)| !plan.children[s].is_empty())
            .max_by(|a, b| {
                plan.subtree_flops[*a.1]
                    .partial_cmp(&plan.subtree_flops[*b.1])
                    .unwrap()
            })
            .map(|(i, &s)| (i, s));
        let Some((idx, s)) = heavy else { break };
        // stop splitting once no subtree carries a meaningful share
        if plan.subtree_flops[s] < 0.05 * total {
            break;
        }
        work.swap_remove(idx);
        in_top[s] = true;
        work.extend_from_slice(&plan.children[s]);
    }

    let mut task_of = vec![NONE; ns];
    let mut task_roots = Vec::with_capacity(work.len());
    for (t, &root) in work.iter().enumerate() {
        task_of[root] = t;
        task_roots.push(root);
    }
    // parents precede children when iterating downwards (child < parent)
    for s in (0..ns).rev() {
        if task_of[s] == NONE && !in_top[s] {
            let p = plan.sparent[s];
            if p != NONE && task_of[p] != NONE {
                task_of[s] = task_of[p];
            }
        }
    }
    Schedule { task_of, task_roots }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooMatrix;
    use crate::util::rng::Rng;

    fn grid(nx: usize, ny: usize) -> CsrMatrix {
        crate::collection::generators::grid2d(nx, ny)
    }

    fn check_plan_invariants(a: &CsrMatrix, p: &SupernodalPlan) {
        let n = a.nrows;
        assert_eq!(p.n, n);
        // post/pnew inverse of each other
        for k in 0..n {
            assert_eq!(p.pnew[p.post[k]], k);
        }
        // supernodes partition the columns
        assert_eq!(p.first[0], 0);
        assert_eq!(*p.first.last().unwrap(), n);
        for w in p.first.windows(2) {
            assert!(w[0] < w[1]);
        }
        let ns = p.n_supernodes();
        for s in 0..ns {
            let (a0, e) = (p.first[s], p.first[s + 1]);
            // boundary rows sorted, beyond the supernode
            for w in p.rows[s].windows(2) {
                assert!(w[0] < w[1]);
            }
            if let Some(&r0) = p.rows[s].first() {
                assert!(r0 >= e);
                assert_eq!(p.sparent[s], p.snode_of(r0));
                assert!(p.sparent[s] > s);
            } else {
                assert_eq!(p.sparent[s], NONE);
            }
            // every column's exact pattern fits inside the panel:
            // later supernode columns plus the boundary row set
            for j in a0..e {
                for &i in &p.li[p.lp[j]..p.lp[j + 1]] {
                    assert!(i > j);
                    assert!(
                        i < e || p.rows[s].binary_search(&i).is_ok(),
                        "snode {s}: col {j} row {i} outside panel"
                    );
                }
            }
        }
        // arena sizing: the recorded peaks bound every front and every
        // child-update set (what FrontArena::begin trusts)
        let mut max_ld2 = 0usize;
        for s in 0..ns {
            let w = p.first[s + 1] - p.first[s];
            let m = p.rows[s].len();
            max_ld2 = max_ld2.max((w + m) * (w + m));
            let child_elems: usize =
                p.children[s].iter().map(|&c| p.rows[c].len().pow(2)).sum();
            assert!(p.stack_peak[s] >= m * m, "snode {s}: own update exceeds peak");
            assert!(p.stack_peak[s] >= child_elems, "snode {s}: children exceed peak");
            for &c in &p.children[s] {
                assert!(p.stack_peak[s] >= p.stack_peak[c], "peak not monotone");
            }
        }
        assert_eq!(p.peak_front, max_ld2);
        assert_eq!(p.peak_front_bytes(), 8 * max_ld2);
        // exact structure totals match the scalar symbolic cost, and the
        // plan's own cost (computed on B) agrees — postorder is an
        // equivalent reordering
        let sym = crate::solver::numeric::analyze(a);
        assert_eq!(p.lp[n] as u64 + n as u64, sym.cost.fill);
        assert_eq!(p.cost, sym.cost);
        // the gather map reproduces the permuted matrix exactly
        let b_ref = a.permute_sym(&p.pnew);
        assert_eq!(p.b_indptr, b_ref.indptr);
        assert_eq!(p.b_indices, b_ref.indices);
        for (k, &src) in p.b_from.iter().enumerate() {
            assert_eq!(a.data[src], b_ref.data[k], "gather slot {k}");
        }
    }

    #[test]
    fn plan_invariants_on_grid() {
        let a = crate::sparse::pattern::symmetrize_spd_like(&grid(12, 9), 2.0);
        let p = plan(&a, &FactorConfig::default());
        check_plan_invariants(&a, &p);
        assert!(p.n_supernodes() < a.nrows, "no columns merged at all");
    }

    #[test]
    fn plan_invariants_on_random() {
        crate::util::prop::check("supernode-plan-random", 10, |rng| {
            let n = rng.range(2, 120);
            let edges = crate::util::prop::random_sym_edges(rng, n, 0.08);
            let mut coo = CooMatrix::new(n, n);
            for i in 0..n {
                coo.push(i, i, 1.0);
            }
            for (i, j) in edges {
                coo.push_sym(i, j, -0.5);
            }
            let a =
                crate::sparse::pattern::symmetrize_spd_like(&coo.to_csr(), 2.0);
            let p = plan(&a, &FactorConfig::default());
            check_plan_invariants(&a, &p);
        });
    }

    #[test]
    fn no_amalgamation_means_no_padding() {
        let a = crate::sparse::pattern::symmetrize_spd_like(&grid(10, 10), 2.0);
        let cfg = FactorConfig {
            relax_ratio: 0.0,
            ..Default::default()
        };
        let p = plan(&a, &cfg);
        assert_eq!(p.padded, 0);
        check_plan_invariants(&a, &p);
    }

    #[test]
    fn amalgamation_reduces_supernode_count() {
        let mut rng = Rng::new(9);
        let raw = crate::collection::generators::banded(300, 6, &mut rng);
        let a = crate::sparse::pattern::symmetrize_spd_like(&raw, 2.0);
        let none = plan(
            &a,
            &FactorConfig {
                relax_ratio: 0.0,
                ..Default::default()
            },
        );
        let relaxed = plan(
            &a,
            &FactorConfig {
                relax_ratio: 0.5,
                ..Default::default()
            },
        );
        assert!(relaxed.n_supernodes() <= none.n_supernodes());
        assert!(relaxed.padded >= none.padded);
    }

    #[test]
    fn schedule_covers_every_supernode_once() {
        let a = crate::sparse::pattern::symmetrize_spd_like(&grid(20, 20), 2.0);
        let p = plan(&a, &FactorConfig::default());
        let sch = schedule(&p, 4);
        let ns = p.n_supernodes();
        for s in 0..ns {
            let t = sch.task_of[s];
            if t == NONE {
                continue; // top set
            }
            assert!(t < sch.task_roots.len());
            // every task member's ancestors up to the root stay in-task
            let root = sch.task_roots[t];
            let mut v = s;
            while v != root {
                v = p.sparent[v];
                assert!(v != NONE, "task member not a descendant of its root");
            }
        }
        // top-set nodes are ancestors: their children are roots or tops
        for s in 0..ns {
            if sch.task_of[s] == NONE {
                for &c in &p.children[s] {
                    assert!(
                        sch.task_of[c] == NONE
                            || sch.task_roots[sch.task_of[c]] == c,
                        "top node {s} has a mid-task child {c}"
                    );
                }
            }
        }
    }

    #[test]
    fn tiny_matrices_plan_cleanly() {
        for n in [0usize, 1, 2] {
            let mut coo = CooMatrix::new(n, n);
            for i in 0..n {
                coo.push(i, i, 2.0);
            }
            let a = coo.to_csr();
            let p = plan(&a, &FactorConfig::default());
            assert_eq!(p.n, n);
            assert_eq!(*p.first.last().unwrap(), n);
        }
    }
}

//! Sparse direct solver — the MUMPS substitute.
//!
//! Pipeline mirrors a direct solver's phases: **analyze** (elimination
//! tree + column counts on the permuted pattern, [`etree`]; plus the
//! assembly tree, [`supernode`], when a supernodal mode is selected),
//! **factorize** (numeric LDLᵀ), **solve** (triangular solves). The
//! solve *time* under a given reordering is the label signal the whole
//! paper is built on; this module measures it.
//!
//! ## Symbolic / numeric split
//!
//! Every artifact of the analyze phase is a pure function of the matrix
//! *pattern* — values never enter the elimination tree, the column
//! counts, the supernode partition, or the amalgamation decisions. The
//! module is therefore organized as an explicit plan/execute split:
//!
//! * **ad-hoc** ([`solve_ordered`]) — analyze + factorize + solve in one
//!   timed call; the dataset sweep's label generator.
//! * **planned** ([`plan`] / [`plan_cache`]) — freeze the whole symbolic
//!   phase (prepared pattern, permutation, etree + postorder, supernode
//!   partition, relaxed amalgamation, column counts, preallocated factor
//!   pattern, and a value-refresh gather) into a
//!   [`SymbolicFactorization`], cache it per
//!   `(pattern, ordering, config)`, and replay requests through the
//!   numeric-only [`factorize_with_plan`] / [`solve_with_plan`]. The
//!   serving engine's warm path runs entirely on this side of the split.
//!   Plans for *drifted* patterns (a few entries inserted/deleted) can be
//!   built by [`SymbolicFactorization::repair`] from a near-match donor
//!   under the donor's frozen permutation — bit-identical to from-scratch
//!   planning, gated by [`RepairConfig`]; the plan cache's near-match
//!   tier drives it (`plan_cache` module docs).
//!
//! ## Invariants
//!
//! All numeric kernels are **pivot-free**: inputs must be SPD-like —
//! structurally symmetric with a strictly dominant positive diagonal,
//! which is what [`prepare`] (`symmetrize_spd_like`) manufactures from
//! arbitrary square matrices (MUMPS with default settings also
//! factorizes such systems without dynamic pivoting). Fill and solve
//! results are ordering-dependent but *mode*-independent: every
//! [`FactorMode`] stores the same factor pattern and produces
//! residual-equivalent solutions.
//!
//! ## Numeric paths ([`FactorConfig`])
//! Three factorization kernels share identical pivot-free LDLᵀ
//! semantics (same `fill()`, residual-equivalent solutions):
//!
//! * [`FactorMode::Scalar`] — up-looking, one column at a time
//!   ([`numeric`]); the reference implementation.
//! * [`FactorMode::Supernodal`] — multifrontal over the postordered
//!   assembly tree with dense cache-blocked panel kernels
//!   ([`supernodal`], [`kernels`]). Relaxed amalgamation
//!   ([`FactorConfig::relax_ratio`]) merges a child supernode into its
//!   parent while the padding it introduces stays under the given
//!   fraction of the exact entries — bigger panels, more BLAS-shaped
//!   work, unchanged stored fill.
//! * [`FactorMode::SupernodalParallel`] — same numerics, scheduled as a
//!   dependency-counted task DAG over the assembly tree
//!   (`util::pool::parallel_dag`): independent subtrees in parallel
//!   *and* a pipelined top of the tree, every front runnable the moment
//!   its last child's update lands; bit-identical to the sequential
//!   supernodal factor.
//!
//! Both supernodal paths draw every dense front and update matrix from
//! per-worker bump arenas ([`arena`]) sized once per plan — the steady
//! state numeric phase makes **zero heap allocations for fronts** — and
//! the factor's structural arrays (`lp`/`li`/`post`) are `Arc`-shared
//! with the plan instead of copied per request.
//!
//! [`SolverConfig::factor`] selects the path for every consumer
//! (dataset sweep, selection pipeline, experiments, benches); the
//! default is the parallel supernodal path with a flop floor below
//! which it degrades to sequential (thread spawn would dominate).
//!
//! ## Flop-cap guard
//! A bad ordering on a mid-size matrix can demand 10¹⁰+ multiply-adds
//! (the paper's Table 1 shows 1000× spreads). To keep the 936-matrix ×
//! 7-algorithm sweep tractable, factorizations whose *symbolic* flop
//! count exceeds [`SolverConfig::flop_cap`] are not run numerically;
//! their time is estimated as `flops / rate` with `rate` calibrated once
//! on this machine by timing a real mid-size factorization. Reports are
//! flagged [`SolveReport::estimated`] and the estimate is continuous with
//! the measured regime (same rate model). DESIGN.md §Substitutions
//! documents this.

pub mod arena;
pub mod etree;
pub mod kernels;
pub mod numeric;
pub mod plan;
pub mod plan_cache;
pub mod supernode;
pub mod supernodal;

use std::sync::OnceLock;

use crate::reorder::Permutation;
use crate::sparse::pattern::symmetrize_spd_like;
use crate::sparse::{CooMatrix, CsrMatrix};
use crate::util::rng::Rng;
use crate::util::Timer;

pub use numeric::{analyze, factorize, FactorError, LdlFactor, Symbolic};
pub use plan::{
    factorize_refreshed, factorize_refreshed_batch, factorize_with_plan,
    factorize_with_plan_batch, plan_solve, plan_solve_prepared, solve_refreshed_batch,
    solve_with_plan, solve_with_plan_batch, NumericWorkspace, RepairConfig,
    SymbolicFactorization,
};
pub use plan_cache::{PlanCache, PlanKey, QuarantineConfig};
pub use supernode::{FactorConfig, FactorMode, SupernodalPlan};
pub use supernodal::{factorize_supernodal, factorize_supernodal_gathered_batch};

/// Solver configuration.
#[derive(Clone, Copy, Debug)]
pub struct SolverConfig {
    /// Diagonal dominance factor applied by [`prepare`].
    pub diag_boost: f64,
    /// Factorizations above this many multiply-adds are estimated, not run.
    pub flop_cap: f64,
    /// Seed for the right-hand side.
    pub seed: u64,
    /// Measure factor+solve this many times and keep the fastest run —
    /// the standard noise-robust estimator for sub-millisecond phases
    /// (labels are decided by these times, so scheduler noise matters).
    pub measure_repeats: usize,
    /// Which numeric factorization to run (and its supernodal knobs).
    pub factor: FactorConfig,
}

impl Default for SolverConfig {
    fn default() -> Self {
        SolverConfig {
            diag_boost: 2.0,
            flop_cap: 2.0e9,
            seed: 0x5eed,
            measure_repeats: 1,
            factor: FactorConfig::default(),
        }
    }
}

impl SolverConfig {
    /// 64-bit fingerprint of every knob a [`SymbolicFactorization`]
    /// depends on — `diag_boost` (shapes the value map's diagonal),
    /// `flop_cap` (decides the capped/estimate path), and the whole
    /// [`FactorConfig`]. Mixed into [`PlanKey`]; `seed` and
    /// `measure_repeats` are deliberately excluded (they affect how a
    /// plan is *measured*, never what it contains).
    pub fn plan_fingerprint(&self) -> u64 {
        let mut h: u64 = 0x9E3779B97F4A7C15;
        let mut mix = |x: u64| {
            h ^= x;
            h = h.wrapping_mul(0x100000001b3).rotate_left(11);
        };
        mix(self.diag_boost.to_bits());
        mix(self.flop_cap.to_bits());
        mix(self.factor.fingerprint());
        h
    }
}

/// Symbolic analysis bundle for a chosen factor path: the symbolic cost
/// (always), plus exactly one of the scalar symbolic (parent/counts) or
/// the supernodal assembly tree — the two paths never both pay their
/// analysis.
pub struct Analysis {
    pub cost: etree::SymbolicCost,
    pub sym: Option<Symbolic>,
    pub plan: Option<SupernodalPlan>,
}

/// Analyze the (already permuted) matrix for the given factor config.
pub fn analyze_with(a: &CsrMatrix, cfg: &FactorConfig) -> Analysis {
    match cfg.mode {
        FactorMode::Scalar => {
            let sym = numeric::analyze(a);
            Analysis {
                cost: sym.cost,
                sym: Some(sym),
                plan: None,
            }
        }
        FactorMode::Supernodal | FactorMode::SupernodalParallel => {
            let plan = supernode::plan(a, cfg);
            Analysis {
                cost: plan.cost,
                sym: None,
                plan: Some(plan),
            }
        }
    }
}

/// Factorize along the path the analysis was built for.
pub fn factorize_with(
    a: &CsrMatrix,
    an: &Analysis,
    cfg: &FactorConfig,
) -> Result<LdlFactor, FactorError> {
    match (&an.sym, &an.plan) {
        (Some(sym), _) => numeric::factorize(a, sym),
        (None, Some(plan)) => supernodal::factorize_supernodal(a, plan, cfg),
        (None, None) => unreachable!("analysis carries neither path"),
    }
}

/// Timing + cost report for one (matrix, ordering) solve.
#[derive(Clone, Copy, Debug)]
pub struct SolveReport {
    /// Time to compute the ordering itself (filled by the caller).
    pub reorder_s: f64,
    pub analyze_s: f64,
    pub factor_s: f64,
    pub solve_s: f64,
    /// nnz(L) including diagonal.
    pub fill: u64,
    /// Symbolic multiply-add count.
    pub flops: f64,
    /// Largest factor column (frontal-size proxy).
    pub max_col: usize,
    /// True if factor+solve times are rate-model estimates (flop cap hit).
    pub estimated: bool,
    /// ‖Ax − b‖₂ of the actual solve (0 when estimated).
    pub residual: f64,
}

impl SolveReport {
    /// The paper's "solution time": analyze + factorize + solve.
    ///
    /// Computing the *ordering itself* is excluded, exactly as in the
    /// paper's setup: RCM and ND orderings are precomputed by external
    /// tools (SciPy/METIS) and "specified as input" to MUMPS (§3.2), so
    /// the recorded MUMPS solve time never includes ordering work. We
    /// apply the same accounting uniformly to all four algorithms (the
    /// ordering cost is still recorded in [`SolveReport::reorder_s`]).
    /// This also keeps labels meaningful on our scaled-down matrices,
    /// where ordering cost would otherwise swamp the factorization cost
    /// the paper's full-size matrices are dominated by.
    pub fn total_s(&self) -> f64 {
        self.analyze_s + self.factor_s + self.solve_s
    }

    /// End-to-end time including computing the ordering.
    pub fn with_reorder_s(&self) -> f64 {
        self.reorder_s + self.total_s()
    }
}

/// Make an arbitrary square matrix solvable by the LDLᵀ kernel:
/// symmetrize and force strict diagonal dominance (see
/// `sparse::pattern::symmetrize_spd_like`).
pub fn prepare(a: &CsrMatrix, cfg: &SolverConfig) -> CsrMatrix {
    symmetrize_spd_like(a, cfg.diag_boost)
}

/// Measured factorization rate (multiply-adds per second), calibrated
/// once per process by factorizing a banded test matrix.
pub fn calibrated_flop_rate() -> f64 {
    static RATE: OnceLock<f64> = OnceLock::new();
    *RATE.get_or_init(|| {
        // band matrix: n=1200, half-bandwidth 40 -> ~2.9M flops, dense
        // enough inner loops to reflect the numeric kernel's throughput.
        let n = 1200;
        let band = 40;
        let mut coo = CooMatrix::new(n, n);
        for i in 0..n {
            coo.push(i, i, (2 * band + 2) as f64);
            for d in 1..=band {
                if i + d < n {
                    coo.push_sym(i, i + d, -1.0);
                }
            }
        }
        let a = coo.to_csr();
        // calibrate the same path real factorizations take, so estimated
        // and measured times stay continuous
        let cfg = FactorConfig::default();
        let an = analyze_with(&a, &cfg);
        // warm once, then time
        let _ = factorize_with(&a, &an, &cfg);
        let t = Timer::start();
        let f = factorize_with(&a, &an, &cfg).expect("calibration factorize");
        let secs = t.elapsed_s().max(1e-6);
        (f.flops / secs).max(1e6)
    })
}

/// Solve the prepared matrix under `perm`, measuring each phase.
/// `a_spd` must already be [`prepare`]d (symmetric, dominant diagonal).
pub fn solve_ordered(
    a_spd: &CsrMatrix,
    perm: &Permutation,
    cfg: &SolverConfig,
) -> Result<SolveReport, FactorError> {
    let t_an = Timer::start();
    let pa = perm.apply(a_spd);
    // scalar symbolic first (O(n + nnz) space): the flop-cap guard must
    // decide *before* the supernodal plan allocates the O(nnz(L)) exact
    // structure a capped factorization would never use
    let sym = numeric::analyze(&pa);
    let cost = sym.cost;

    if cost.flops > cfg.flop_cap {
        let analyze_s = t_an.elapsed_s();
        let rate = calibrated_flop_rate();
        // solve streams L twice (fwd+bwd): ~4 ops per factor entry
        let factor_s = cost.flops / rate;
        let solve_s = 4.0 * cost.fill as f64 / rate;
        return Ok(SolveReport {
            reorder_s: 0.0,
            analyze_s,
            factor_s,
            solve_s,
            fill: cost.fill,
            flops: cost.flops,
            max_col: cost.max_col,
            estimated: true,
            residual: 0.0,
        });
    }

    let an = match cfg.factor.mode {
        FactorMode::Scalar => Analysis {
            cost,
            sym: Some(sym),
            plan: None,
        },
        FactorMode::Supernodal | FactorMode::SupernodalParallel => Analysis {
            cost,
            sym: None,
            plan: Some(supernode::plan_with(&pa, &sym, &cfg.factor)),
        },
    };
    let analyze_s = t_an.elapsed_s();

    let t_f = Timer::start();
    let mut f = factorize_with(&pa, &an, &cfg.factor)?;
    let mut factor_s = t_f.elapsed_s();

    // random RHS, as the paper's preprocessing scripts generate
    let n = pa.nrows;
    let mut rng = Rng::new(cfg.seed);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let t_s = Timer::start();
    let mut x = f.solve(&b);
    let mut solve_s = t_s.elapsed_s();

    // extra timed repeats: keep the fastest measurement of each phase
    for _ in 1..cfg.measure_repeats.max(1) {
        let t_f = Timer::start();
        f = factorize_with(&pa, &an, &cfg.factor)?;
        factor_s = factor_s.min(t_f.elapsed_s());
        let t_s = Timer::start();
        x = f.solve(&b);
        solve_s = solve_s.min(t_s.elapsed_s());
    }

    let ax = pa.matvec(&x);
    let residual = ax
        .iter()
        .zip(&b)
        .map(|(axi, bi)| (axi - bi).powi(2))
        .sum::<f64>()
        .sqrt();

    Ok(SolveReport {
        reorder_s: 0.0,
        analyze_s,
        factor_s,
        solve_s,
        fill: f.fill(),
        flops: f.flops,
        max_col: cost.max_col,
        estimated: false,
        residual,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reorder::ReorderAlgorithm;

    fn grid_matrix(nx: usize, ny: usize) -> CsrMatrix {
        let idx = |x: usize, y: usize| y * nx + x;
        let n = nx * ny;
        let mut coo = CooMatrix::new(n, n);
        for y in 0..ny {
            for x in 0..nx {
                let v = idx(x, y);
                coo.push(v, v, 4.0);
                if x + 1 < nx {
                    coo.push_sym(v, idx(x + 1, y), -1.0);
                }
                if y + 1 < ny {
                    coo.push_sym(v, idx(x, y + 1), -1.0);
                }
            }
        }
        coo.to_csr()
    }

    #[test]
    fn solve_ordered_accurate_under_all_orderings() {
        let cfg = SolverConfig::default();
        let a = prepare(&grid_matrix(12, 12), &cfg);
        for alg in [
            ReorderAlgorithm::Natural,
            ReorderAlgorithm::Rcm,
            ReorderAlgorithm::Amd,
            ReorderAlgorithm::Nd,
            ReorderAlgorithm::Scotch,
        ] {
            let p = alg.compute(&a, 3);
            let r = solve_ordered(&a, &p, &cfg).unwrap();
            assert!(!r.estimated);
            assert!(r.residual < 1e-8, "{alg}: residual {}", r.residual);
            assert!(r.fill >= 144);
            assert!(r.total_s() > 0.0);
        }
    }

    #[test]
    fn fill_depends_on_ordering() {
        let cfg = SolverConfig::default();
        let a = prepare(&grid_matrix(16, 16), &cfg);
        let nat = solve_ordered(&a, &Permutation::identity(256), &cfg)
            .unwrap()
            .fill;
        let amd = solve_ordered(
            &a,
            &ReorderAlgorithm::Amd.compute(&a, 1),
            &cfg,
        )
        .unwrap()
        .fill;
        assert!(amd < nat, "amd fill {amd} >= natural {nat}");
    }

    #[test]
    fn flop_cap_switches_to_estimate() {
        let cfg = SolverConfig {
            flop_cap: 10.0, // absurdly low: force the estimate path
            ..Default::default()
        };
        let a = prepare(&grid_matrix(10, 10), &cfg);
        let r = solve_ordered(&a, &Permutation::identity(100), &cfg).unwrap();
        assert!(r.estimated);
        assert!(r.factor_s > 0.0);
        assert_eq!(r.residual, 0.0);
    }

    #[test]
    fn estimate_continuous_with_measurement() {
        // measured and estimated times for the same matrix should agree
        // within an order of magnitude (the rate model is coarse but sane)
        let a = {
            let cfg = SolverConfig::default();
            prepare(&grid_matrix(30, 30), &cfg)
        };
        let p = ReorderAlgorithm::Amd.compute(&a, 1);
        let measured = solve_ordered(&a, &p, &SolverConfig::default()).unwrap();
        let estimated = solve_ordered(
            &a,
            &p,
            &SolverConfig {
                flop_cap: 1.0,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(estimated.estimated && !measured.estimated);
        let ratio = estimated.factor_s / measured.factor_s.max(1e-9);
        assert!(
            (0.02..50.0).contains(&ratio),
            "estimate off by {ratio}x"
        );
    }

    #[test]
    fn calibration_rate_is_plausible() {
        let r = calibrated_flop_rate();
        assert!(r > 1e6 && r < 1e12, "rate {r}");
    }

    #[test]
    fn all_factor_modes_agree_through_solve_ordered() {
        let base = SolverConfig::default();
        let a = prepare(&grid_matrix(20, 17), &base);
        let p = ReorderAlgorithm::Amd.compute(&a, 7);
        let mut fills = Vec::new();
        for mode in [
            FactorMode::Scalar,
            FactorMode::Supernodal,
            FactorMode::SupernodalParallel,
        ] {
            let cfg = SolverConfig {
                factor: FactorConfig {
                    mode,
                    parallel_flop_min: 0.0,
                    ..FactorConfig::default()
                },
                ..base
            };
            let r = solve_ordered(&a, &p, &cfg).unwrap();
            assert!(!r.estimated);
            assert!(r.residual < 1e-8, "{mode:?}: residual {}", r.residual);
            fills.push(r.fill);
        }
        assert!(
            fills.windows(2).all(|w| w[0] == w[1]),
            "fill differs across modes: {fills:?}"
        );
    }
}

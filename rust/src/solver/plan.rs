//! The solve plan: everything symbolic about one `(pattern, ordering,
//! factor config)`, computed once and replayed numerically forever.
//!
//! [`solve_ordered`](crate::solver::solve_ordered) pays four symbolic
//! costs on every call even when the answer cannot change: the
//! symmetrization (`prepare`), the symmetric permutation, the
//! elimination-tree analysis, and (on the supernodal path) the assembly
//! tree + exact factor structure. All of them are pure functions of the
//! *pattern* — values never enter — so a serving path that re-solves one
//! structural pattern under many numerics recomputes identical artifacts
//! per request. [`SymbolicFactorization`] freezes them:
//!
//! * the ordering itself ([`SymbolicFactorization::perm`], shared with
//!   the ordering cache as an `Arc`);
//! * the pattern of the permuted prepared matrix `PA = P·S·Pᵀ`
//!   (`S = symmetrize_spd_like(A)`) — stored directly for the scalar
//!   path, or as the postordered [`SupernodalPlan`] (permuted etree,
//!   postorder, supernode partition, relaxed amalgamation, column
//!   counts, preallocated factor pattern) for the multifrontal path;
//! * the scalar symbolic ([`Symbolic`]: etree parents + column counts)
//!   when the scalar kernel will consume it;
//! * a **value map**: for every slot of the numeric kernel's input
//!   layout, which raw `A` slots feed it and how the dominant diagonal
//!   is rebuilt. Its `refresh` replays `prepare` + permutation
//!   (+ postorder gather) as one O(nnz) gather into a pooled buffer —
//!   no transpose, no sort, no graph, no allocation.
//!
//! [`factorize_with_plan`] is then *numeric only*: refresh values,
//! factorize (scalar or supernodal, sequential or parallel — the mode is
//! frozen in the plan's [`FactorConfig`]). It is bit-identical to the
//! from-scratch path by construction: the refresh performs the same
//! floating-point operations in the same order as
//! `symmetrize_spd_like` + `permute_sym`, and the kernels are the very
//! same functions (`tests/prop_symbolic_plan.rs` holds that line across
//! all seven algorithms and all three factor modes).
//!
//! Plans are cached per `(PatternKey, algorithm, seed, config)` by
//! [`crate::solver::plan_cache::PlanCache`]; the serving engine's warm
//! path goes predicted label → cached plan → [`solve_with_plan`] with
//! zero symbolic work.
//!
//! **Incremental repair.** When a pattern *drifts* (a Newton step or
//! adaptive mesh adds/removes a handful of entries), the exact key
//! misses but the old plan is almost right. Every uncapped plan retains
//! its raw base pattern, its scalar symbolic, and its `spd → kernel`
//! slot map, and [`SymbolicFactorization::repair`] turns those into a
//! new plan for the drifted matrix **under the frozen permutation** —
//! skipping the reorderer, the adjacency-graph build, and the numeric
//! symmetrization that dominate a cold miss. Bit-identity with
//! from-scratch planning under the same permutation is by construction,
//! not by incremental surgery: planning is value-pure, so repair feeds
//! the same planning code a zero-valued carrier of the drifted spd
//! *pattern* ([`crate::sparse::spd_pattern`]) and lets exact-equality
//! certificates (`supernode::plan_with_reuse`, and a symmetrized-pattern
//! fingerprint fast path that reuses every symbolic artifact verbatim)
//! recover the sharing. Past the [`RepairConfig`] drift threshold — or
//! when any edit touches a separator-grade supernode — `repair` returns
//! `None` and the caller falls back to a cold analysis.
//!
//! When *several* requests share one plan, the batched entries
//! ([`factorize_with_plan_batch`] / [`solve_with_plan_batch`], plus the
//! value-level [`solve_refreshed_batch`] the serving admission layer
//! uses) refresh each lane and hand all value sets to **one**
//! multifrontal traversal over lane-interleaved fronts
//! ([`crate::solver::supernodal::factorize_supernodal_gathered_batch`]).
//! Every lane's factor, solve, and even zero-pivot error is bit-identical
//! to its own single-request call — batching changes throughput, never
//! results. Scalar plans simply loop (the scalar kernel has no batched
//! form); capped plans return the same per-lane estimate the single path
//! would.

use std::sync::Arc;

use super::etree::SymbolicCost;
use super::numeric::{self, FactorError, LdlFactor, Symbolic};
use super::supernode::{self, FactorConfig, FactorMode, SupernodalPlan};
use super::supernodal;
use super::{calibrated_flop_rate, prepare, SolveReport, SolverConfig};
use crate::reorder::Permutation;
use crate::sparse::{pattern_diff_parts, spd_pattern, CsrMatrix, PatternDiff, PatternKey};
use crate::util::rng::Rng;
use crate::util::Timer;

/// Sentinel for "no source slot" in a [`ValueMap`].
const NO_SLOT: usize = usize::MAX;

/// Pooled numeric scratch for plan-based factorization: holds the
/// refreshed value buffer between requests so the steady-state path
/// allocates nothing for its input values. Check one out of an
/// `ObjectPool` (the serving engine does) or keep one per thread.
#[derive(Default)]
pub struct NumericWorkspace {
    /// Refreshed values in the plan's kernel layout (`PA` for scalar,
    /// postordered `B` for supernodal).
    pub(crate) vals: Vec<f64>,
}

impl NumericWorkspace {
    pub fn new() -> Self {
        Self::default()
    }
}

/// The value-refresh program: replays `symmetrize_spd_like` + the
/// symmetric permutation (+ the supernodal postorder gather) as a single
/// O(nnz) pass from raw `A` values into the numeric kernel's input
/// layout, bit-identically (same operations, same order — the diagonal's
/// off-row absolute sums are accumulated in the original row/column
/// order `symmetrize_spd_like` uses, whatever the target layout).
#[derive(Clone, Debug)]
struct ValueMap {
    /// Target slot count (nnz of the prepared matrix).
    nnz_target: usize,
    /// Per target slot, the ≤2 raw `A` slots feeding it: the stored
    /// `(r,c)` and `(c,r)` entries ([`NO_SLOT`] = absent). Diagonal
    /// slots are `[NO_SLOT, NO_SLOT]` (their value is derived, pass 2).
    src: Vec<[usize; 2]>,
    /// Off-diagonal target slots of prepared row `i`, grouped by row in
    /// ascending original column order (`sum_ptr[i]..sum_ptr[i+1]`) —
    /// exactly the accumulation order of `symmetrize_spd_like`'s
    /// dominance sums.
    sum_ptr: Vec<usize>,
    sum_slots: Vec<usize>,
    /// Target slot of prepared row `i`'s diagonal.
    diag_of: Vec<usize>,
    diag_boost: f64,
}

impl ValueMap {
    /// Build the map for prepared matrix `spd` of raw `a`, with
    /// `s2t[k]` = target slot of `spd` slot `k` (identity-composable:
    /// pass the `spd → PA` map for the scalar layout, or its composition
    /// with the postorder gather for the supernodal layout).
    fn build(a: &CsrMatrix, spd: &CsrMatrix, s2t: &[usize], diag_boost: f64) -> ValueMap {
        let n = a.nrows;
        let slot_of = |r: usize, c: usize| -> usize {
            match a.row_indices(r).binary_search(&c) {
                Ok(p) => a.indptr[r] + p,
                Err(_) => NO_SLOT,
            }
        };
        let mut src = vec![[NO_SLOT; 2]; spd.nnz()];
        let mut sum_ptr = vec![0usize; n + 1];
        let mut sum_slots = Vec::with_capacity(spd.nnz().saturating_sub(n));
        let mut diag_of = vec![0usize; n];
        for r in 0..n {
            for k in spd.indptr[r]..spd.indptr[r + 1] {
                let c = spd.indices[k];
                let t = s2t[k];
                if c == r {
                    diag_of[r] = t;
                } else {
                    sum_slots.push(t);
                    src[t] = [slot_of(r, c), slot_of(c, r)];
                }
            }
            sum_ptr[r + 1] = sum_slots.len();
        }
        ValueMap {
            nnz_target: spd.nnz(),
            src,
            sum_ptr,
            sum_slots,
            diag_of,
            diag_boost,
        }
    }

    /// Refresh `out` with the prepared+permuted values of `a` (two
    /// passes: symmetrized off-diagonals, then the dominant diagonal).
    fn refresh(&self, a: &CsrMatrix, out: &mut Vec<f64>) {
        out.clear();
        out.resize(self.nnz_target, 0.0);
        let at = |s: usize| if s == NO_SLOT { 0.0 } else { a.data[s] };
        for (k, s) in self.src.iter().enumerate() {
            out[k] = (at(s[0]) + at(s[1])) / 2.0;
        }
        for (r, &d) in self.diag_of.iter().enumerate() {
            let mut off = 0.0f64;
            for &t in &self.sum_slots[self.sum_ptr[r]..self.sum_ptr[r + 1]] {
                off += out[t].abs();
            }
            out[d] = self.diag_boost * (1.0 + off);
        }
    }
}

/// A frozen symbolic factorization: the solve plan for one
/// `(pattern, ordering, factor config)`. See the module docs for what it
/// carries; consume it with [`factorize_with_plan`] /
/// [`solve_with_plan`]. Plans are pattern-pure — numerically-different
/// matrices with one structure share a plan, which is why the
/// [`crate::solver::plan_cache::PlanCache`] can hand one `Arc` to every
/// concurrent request.
pub struct SymbolicFactorization {
    n: usize,
    /// nnz of the raw matrix this plan's value map was built for (guards
    /// against consuming a plan with a structurally different matrix).
    raw_nnz: usize,
    /// The ordering the plan bakes in (shared with the ordering cache).
    pub perm: Arc<Permutation>,
    /// The factor configuration the numeric phase will run under.
    pub factor: FactorConfig,
    /// Symbolic cost of the factorization (fill / flops / max column).
    pub cost: SymbolicCost,
    /// True when `cost.flops` exceeded the planning `flop_cap`: the plan
    /// carries no numeric structures and [`solve_with_plan`] returns the
    /// rate-model estimate, exactly like `solve_ordered`.
    pub capped: bool,
    /// Etree parents + column counts of `PA` — consumed by the scalar
    /// kernel, and retained on the supernodal path too as the repair
    /// path's reusable symbolic (`None` only when `capped`).
    sym: Option<Symbolic>,
    /// Scalar path: pattern of `PA` (`indptr`, `indices`).
    pa_pattern: Option<(Vec<usize>, Vec<usize>)>,
    /// Supernodal path: the postordered assembly-tree plan.
    snplan: Option<SupernodalPlan>,
    /// Value-refresh program (`None` only when `capped`).
    vals: Option<ValueMap>,
    /// Raw base pattern the plan was built from (`None` when `capped`):
    /// what the near-match tier diffs an incoming matrix against, and
    /// what chained repairs re-diff from.
    raw_pattern: Option<(Vec<usize>, Vec<usize>)>,
    /// Fingerprint of the prepared (symmetrized) pattern — the repair
    /// fast path's certificate that a drift left the spd structure, and
    /// therefore every symbolic artifact, unchanged.
    spd_key: PatternKey,
    /// `spd slot → kernel slot` map (`None` when `capped`): rebuilding
    /// only the value map on the fast path needs it.
    s2t: Option<Vec<usize>>,
    /// [`SolverConfig::plan_fingerprint`] the plan was built under —
    /// repair refuses donors planned with different knobs.
    config_fp: u64,
}

/// Drift thresholds for [`SymbolicFactorization::repair`]. Defaults are
/// deliberately conservative: repair exists to absorb the
/// few-entries-per-step drift of factorization-in-loop workloads, not to
/// chase structurally different matrices with a stale permutation.
#[derive(Clone, Copy, Debug)]
pub struct RepairConfig {
    /// Repair is attempted only while `|diff| ≤ max_drift · nnz`
    /// (against the larger of the donor's and the incoming nnz). Past
    /// it, fill quality under the frozen permutation is unvouched — fall
    /// back to a cold reorder.
    pub max_drift: f64,
    /// Supernodal gate: an edit endpoint landing in a supernode whose
    /// subtree carries at least this fraction of total flops (a
    /// separator-grade node — its structure feeds most of the
    /// elimination) forces fallback.
    pub separator_flops: f64,
}

impl Default for RepairConfig {
    fn default() -> Self {
        RepairConfig {
            max_drift: 0.05,
            separator_flops: 0.5,
        }
    }
}

impl SymbolicFactorization {
    pub fn n(&self) -> usize {
        self.n
    }

    /// The supernodal assembly-tree plan, when this plan factors
    /// multifrontally.
    pub fn supernodal(&self) -> Option<&SupernodalPlan> {
        self.snplan.as_ref()
    }

    /// The raw base pattern this plan was built from (`None` for capped
    /// plans, which retain no repair state).
    pub fn raw_pattern(&self) -> Option<(&[usize], &[usize])> {
        self.raw_pattern
            .as_ref()
            .map(|(p, i)| (p.as_slice(), i.as_slice()))
    }

    /// The [`SolverConfig::plan_fingerprint`] this plan was built under.
    pub fn config_fingerprint(&self) -> u64 {
        self.config_fp
    }

    /// Structural diff from this plan's base pattern to `a` — the
    /// near-match tier's drift measurement. `None` when the plan keeps
    /// no base pattern (capped) or the orders differ.
    pub fn diff_against(&self, a: &CsrMatrix) -> Option<PatternDiff> {
        if a.nrows != self.n || a.ncols != self.n {
            return None;
        }
        let (indptr, indices) = self.raw_pattern.as_ref()?;
        Some(pattern_diff_parts(
            self.n, indptr, indices, &a.indptr, &a.indices,
        ))
    }

    /// Incremental replanning: build a plan for the drifted matrix `a`
    /// (at structural distance `diff` from this plan's base pattern)
    /// **under this plan's frozen permutation**, skipping everything a
    /// cold miss pays before planning — reordering, the adjacency-graph
    /// build, and numeric symmetrization ([`crate::sparse::spd_pattern`]
    /// derives the prepared *structure* without touching values, which
    /// suffices because planning is value-pure). Returns `None` when the
    /// repair is refused: the donor is capped or was planned under
    /// different knobs, the drift exceeds [`RepairConfig::max_drift`],
    /// an edit touches a separator-grade supernode
    /// ([`RepairConfig::separator_flops`]), or the drifted cost crosses
    /// the flop cap (serving estimates off a stale permutation would be
    /// worse than a cold reorder).
    ///
    /// The result is bit-identical to `plan_solve(a, self.perm, cfg)` —
    /// factor values, permutation, fill, and value-refresh gather — by
    /// construction: both run the same value-pure planning code on the
    /// same structure (`tests/prop_symbolic_plan.rs` holds that line
    /// across all seven algorithms, three factor modes, and chained
    /// repairs). When the drift leaves the symmetrized pattern itself
    /// unchanged (edits that only toggle one-sided storage of surviving
    /// edges), a fingerprint fast path reuses every symbolic artifact
    /// verbatim and rebuilds only the value map's raw-slot sources.
    pub fn repair(
        &self,
        a: &CsrMatrix,
        diff: &PatternDiff,
        cfg: &SolverConfig,
        rcfg: &RepairConfig,
    ) -> Option<SymbolicFactorization> {
        if self.capped || a.nrows != self.n || a.ncols != self.n || diff.n != self.n {
            return None;
        }
        if cfg.plan_fingerprint() != self.config_fp {
            return None;
        }
        let budget = rcfg.max_drift * self.raw_nnz.max(a.nnz()) as f64;
        if diff.len() as f64 > budget {
            return None;
        }
        if let Some(sn) = &self.snplan {
            // separator gate: map each edit endpoint through the frozen
            // permutation and the postorder into the old plan's supernode
            // partition; a hit on a subtree carrying most of the flops
            // means the edit perturbs the top of the elimination
            let total = sn.total_flops().max(1.0);
            let p = self.perm.as_slice();
            for (r, c) in diff.edges() {
                for v in [r, c] {
                    let s = sn.snode_of(sn.pnew[p[v]]);
                    if sn.subtree_flops[s] >= rcfg.separator_flops * total {
                        return None;
                    }
                }
            }
        }

        // pattern-only symmetrization: a zero-valued carrier of the
        // drifted spd structure plans bit-identically to the fully
        // symmetrized matrix (planning never reads values)
        let (indptr, indices) = spd_pattern(a);
        let nnz_spd = indices.len();
        let spd = CsrMatrix {
            nrows: self.n,
            ncols: self.n,
            indptr,
            indices,
            data: vec![0.0; nnz_spd],
        };

        let repaired = if self.spd_key == PatternKey::of(&spd) {
            // fast path: the drift only toggled one-sided storage of
            // edges whose symmetrized union survives, so the prepared
            // pattern — and with it every symbolic artifact — is
            // unchanged. Only the value map's raw-slot sources moved.
            let s2t = self.s2t.as_ref().expect("uncapped plans keep s2t");
            let vals = ValueMap::build(a, &spd, s2t, cfg.diag_boost);
            SymbolicFactorization {
                n: self.n,
                raw_nnz: a.nnz(),
                perm: self.perm.clone(),
                factor: self.factor,
                cost: self.cost,
                capped: false,
                sym: self.sym.clone(),
                pa_pattern: self.pa_pattern.clone(),
                snplan: self.snplan.clone(),
                vals: Some(vals),
                raw_pattern: Some((a.indptr.clone(), a.indices.clone())),
                spd_key: self.spd_key,
                s2t: Some(s2t.clone()),
                config_fp: self.config_fp,
            }
        } else {
            plan_prepared_reusing(a, &spd, self.perm.clone(), cfg, Some(self))
        };
        if repaired.capped {
            return None;
        }
        Some(repaired)
    }

    /// Peak dense frontal-matrix footprint in bytes of the multifrontal
    /// numeric phase (the per-worker arena sizing; 0 for scalar or
    /// capped plans). Reported as `peak_front_bytes` by `bench_solver`.
    pub fn peak_front_bytes(&self) -> usize {
        self.snplan.as_ref().map_or(0, |p| p.peak_front_bytes())
    }

    /// Refresh `ws` with this plan's kernel-layout values of `a` — the
    /// pure value-gather half of [`factorize_with_plan`], exposed so the
    /// serving admission layer can refresh each batch member into its
    /// own buffer before the shared traversal.
    pub fn refresh_values(&self, a: &CsrMatrix, ws: &mut NumericWorkspace) {
        assert!(!self.capped, "capped plans carry no numeric structure");
        assert_eq!(a.nrows, self.n, "plan built for a different order");
        assert_eq!(a.nnz(), self.raw_nnz, "plan built for a different pattern");
        self.vals
            .as_ref()
            .expect("uncapped plans carry a value map")
            .refresh(a, &mut ws.vals);
    }

    /// ‖PA·x − b‖₂ over the plan's stored pattern and the refreshed
    /// values in `vals` (`x`, `b` in the `PA` numbering).
    fn residual(&self, vals: &[f64], x: &[f64], b: &[f64]) -> f64 {
        let mut r2 = 0.0f64;
        match (&self.pa_pattern, &self.snplan) {
            (Some((indptr, indices)), _) => {
                for r in 0..self.n {
                    let mut acc = 0.0;
                    for k in indptr[r]..indptr[r + 1] {
                        acc += vals[k] * x[indices[k]];
                    }
                    let d = acc - b[r];
                    r2 += d * d;
                }
            }
            (None, Some(sn)) => {
                // row k of the postordered B is row post[k] of PA, its
                // column j is PA column post[j]
                for k in 0..self.n {
                    let mut acc = 0.0;
                    for t in sn.b_indptr[k]..sn.b_indptr[k + 1] {
                        acc += vals[t] * x[sn.post[sn.b_indices[t]]];
                    }
                    let d = acc - b[sn.post[k]];
                    r2 += d * d;
                }
            }
            (None, None) => return 0.0,
        }
        r2.sqrt()
    }
}

/// Plan a solve: prepare (symmetrize) the raw matrix, then freeze every
/// symbolic artifact of factorizing it under `perm` with `cfg`. This is
/// the once-per-pattern cost the plan cache amortizes away.
pub fn plan_solve(
    a: &CsrMatrix,
    perm: Arc<Permutation>,
    cfg: &SolverConfig,
) -> SymbolicFactorization {
    let spd = prepare(a, cfg);
    plan_solve_prepared(a, &spd, perm, cfg)
}

/// [`plan_solve`] for a caller that already prepared the matrix (`spd`
/// must be `prepare(a, cfg)` — the serving cold path and the pipeline
/// share their one symmetrization with the feature extractor this way).
pub fn plan_solve_prepared(
    a: &CsrMatrix,
    spd: &CsrMatrix,
    perm: Arc<Permutation>,
    cfg: &SolverConfig,
) -> SymbolicFactorization {
    plan_prepared_reusing(a, spd, perm, cfg, None)
}

/// The shared planning body: [`plan_solve_prepared`] with an optional
/// donor plan whose `Arc`ed structures are adopted when the fresh
/// computation reproduces them bit-for-bit (see
/// `supernode::plan_with_reuse`). The repair path passes the drifted
/// pattern's donor; the cold path passes `None`. Everything symbolic is
/// always computed fresh — reuse is a sharing optimization, never a
/// correctness shortcut.
fn plan_prepared_reusing(
    a: &CsrMatrix,
    spd: &CsrMatrix,
    perm: Arc<Permutation>,
    cfg: &SolverConfig,
    donor: Option<&SymbolicFactorization>,
) -> SymbolicFactorization {
    assert_eq!(a.nrows, a.ncols, "plans need a square matrix");
    assert_eq!(spd.nrows, a.nrows, "prepared matrix shape mismatch");
    assert_eq!(perm.len(), a.nrows, "permutation length mismatch");
    let n = a.nrows;
    let spd_key = PatternKey::of(spd);
    let config_fp = cfg.plan_fingerprint();
    let pa = perm.apply(spd);
    // scalar symbolic first (O(n + nnz) space): the flop-cap guard must
    // decide before the supernodal plan allocates the O(nnz(L)) exact
    // structure a capped factorization would never use
    let sym = numeric::analyze(&pa);
    let cost = sym.cost;
    if cost.flops > cfg.flop_cap {
        return SymbolicFactorization {
            n,
            raw_nnz: a.nnz(),
            perm,
            factor: cfg.factor,
            cost,
            capped: true,
            sym: None,
            pa_pattern: None,
            snplan: None,
            vals: None,
            raw_pattern: None,
            spd_key,
            s2t: None,
            config_fp,
        };
    }

    // spd slot -> PA slot (spd entry (r, c) lands at (perm[r], perm[c]))
    let p = perm.as_slice();
    let mut s2pa = vec![0usize; spd.nnz()];
    for r in 0..n {
        let nr = p[r];
        let prow = &pa.indices[pa.indptr[nr]..pa.indptr[nr + 1]];
        for k in spd.indptr[r]..spd.indptr[r + 1] {
            let pos = prow
                .binary_search(&p[spd.indices[k]])
                .expect("permuted entry present");
            s2pa[k] = pa.indptr[nr] + pos;
        }
    }
    let raw_pattern = Some((a.indptr.clone(), a.indices.clone()));

    match cfg.factor.mode {
        FactorMode::Scalar => {
            let vals = ValueMap::build(a, spd, &s2pa, cfg.diag_boost);
            SymbolicFactorization {
                n,
                raw_nnz: a.nnz(),
                perm,
                factor: cfg.factor,
                cost,
                capped: false,
                sym: Some(sym),
                pa_pattern: Some((pa.indptr, pa.indices)),
                snplan: None,
                vals: Some(vals),
                raw_pattern,
                spd_key,
                s2t: Some(s2pa),
                config_fp,
            }
        }
        FactorMode::Supernodal | FactorMode::SupernodalParallel => {
            let snplan = supernode::plan_with_reuse(
                &pa,
                &sym,
                &cfg.factor,
                donor.and_then(|d| d.snplan.as_ref()),
            );
            // compose with the postorder gather: target layout becomes B
            let mut pa2b = vec![0usize; pa.nnz()];
            for (kb, &ks) in snplan.b_from.iter().enumerate() {
                pa2b[ks] = kb;
            }
            for t in s2pa.iter_mut() {
                *t = pa2b[*t];
            }
            let vals = ValueMap::build(a, spd, &s2pa, cfg.diag_boost);
            SymbolicFactorization {
                n,
                raw_nnz: a.nnz(),
                perm,
                factor: cfg.factor,
                cost,
                capped: false,
                sym: Some(sym),
                pa_pattern: None,
                snplan: Some(snplan),
                vals: Some(vals),
                raw_pattern,
                spd_key,
                s2t: Some(s2pa),
                config_fp,
            }
        }
    }
}

/// Numeric-only factorization: refresh the plan's input values from the
/// raw matrix into the pooled workspace, then run the kernel the plan
/// was built for (scalar up-looking, or supernodal multifrontal —
/// sequential or subtree-parallel per the frozen [`FactorConfig`]). No
/// symmetrization, no permutation, no symbolic analysis, no pattern
/// allocation. Bit-identical to `analyze_with` + `factorize_with` on the
/// freshly prepared-and-permuted matrix.
pub fn factorize_with_plan(
    a: &CsrMatrix,
    plan: &SymbolicFactorization,
    ws: &mut NumericWorkspace,
) -> Result<LdlFactor, FactorError> {
    plan.refresh_values(a, ws);
    factorize_refreshed(plan, &ws.vals)
}

/// The kernel-dispatch half of [`factorize_with_plan`]: factor values
/// already refreshed into the plan's kernel layout. This is the
/// single-lane form of [`factorize_refreshed_batch`].
pub fn factorize_refreshed(
    plan: &SymbolicFactorization,
    vals: &[f64],
) -> Result<LdlFactor, FactorError> {
    assert!(!plan.capped, "capped plans carry no numeric structure");
    // dispatch on the kernel structure: supernodal plans also retain the
    // scalar symbolic (repair state), so `snplan` decides the path
    match (&plan.snplan, &plan.sym) {
        (Some(sn), _) => supernodal::factorize_supernodal_gathered(vals, sn, &plan.factor),
        (None, Some(sym)) => {
            let (indptr, indices) = plan
                .pa_pattern
                .as_ref()
                .expect("scalar plans keep the permuted pattern");
            numeric::factorize_parts(plan.n, indptr, indices, vals, sym)
        }
        (None, None) => unreachable!("plan carries neither path"),
    }
}

/// Factor `k` refreshed value sets sharing one plan in a single batched
/// traversal (supernodal plans; scalar plans loop — the scalar kernel
/// has no batched form). Each lane's result — factor or error — is
/// bit-identical to its own [`factorize_refreshed`] call; see
/// [`crate::solver::supernodal::factorize_supernodal_gathered_batch`]
/// for the contract.
pub fn factorize_refreshed_batch(
    plan: &SymbolicFactorization,
    valss: &[&[f64]],
) -> Vec<Result<LdlFactor, FactorError>> {
    assert!(!plan.capped, "capped plans carry no numeric structure");
    match &plan.snplan {
        Some(sn) => supernodal::factorize_supernodal_gathered_batch(valss, sn, &plan.factor),
        None => valss
            .iter()
            .map(|vals| factorize_refreshed(plan, vals))
            .collect(),
    }
}

/// Batched [`factorize_with_plan`]: refresh each matrix into its own
/// workspace, then factor all of them in one traversal. `mats[i]` pairs
/// with `wss[i]`; every matrix must share the plan's pattern.
pub fn factorize_with_plan_batch(
    mats: &[&CsrMatrix],
    plan: &SymbolicFactorization,
    wss: &mut [NumericWorkspace],
) -> Vec<Result<LdlFactor, FactorError>> {
    assert_eq!(mats.len(), wss.len(), "one workspace per batched matrix");
    for (a, ws) in mats.iter().zip(wss.iter_mut()) {
        plan.refresh_values(a, ws);
    }
    let valss: Vec<&[f64]> = wss.iter().map(|w| w.vals.as_slice()).collect();
    factorize_refreshed_batch(plan, &valss)
}

/// The plan-consuming counterpart of `solve_ordered`: numeric factorize
/// + triangular solves + residual, every phase timed, honoring the
/// flop-cap estimate and `measure_repeats` exactly like the from-scratch
/// path. `analyze_s` is 0 by construction — that is the point.
pub fn solve_with_plan(
    a: &CsrMatrix,
    plan: &SymbolicFactorization,
    cfg: &SolverConfig,
    ws: &mut NumericWorkspace,
) -> Result<SolveReport, FactorError> {
    let cost = plan.cost;
    if plan.capped {
        let rate = calibrated_flop_rate();
        return Ok(SolveReport {
            reorder_s: 0.0,
            analyze_s: 0.0,
            factor_s: cost.flops / rate,
            solve_s: 4.0 * cost.fill as f64 / rate,
            fill: cost.fill,
            flops: cost.flops,
            max_col: cost.max_col,
            estimated: true,
            residual: 0.0,
        });
    }

    let t_f = Timer::start();
    let mut f = factorize_with_plan(a, plan, ws)?;
    let mut factor_s = t_f.elapsed_s();

    // same RHS stream as `solve_ordered`, so solutions compare bitwise
    let n = plan.n;
    let mut rng = Rng::new(cfg.seed);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let t_s = Timer::start();
    let mut x = f.solve(&b);
    let mut solve_s = t_s.elapsed_s();

    for _ in 1..cfg.measure_repeats.max(1) {
        let t_f = Timer::start();
        f = factorize_with_plan(a, plan, ws)?;
        factor_s = factor_s.min(t_f.elapsed_s());
        let t_s = Timer::start();
        x = f.solve(&b);
        solve_s = solve_s.min(t_s.elapsed_s());
    }

    let residual = plan.residual(&ws.vals, &x, &b);
    Ok(SolveReport {
        reorder_s: 0.0,
        analyze_s: 0.0,
        factor_s,
        solve_s,
        fill: f.fill(),
        flops: f.flops,
        max_col: cost.max_col,
        estimated: false,
        residual,
    })
}

/// Batched [`solve_with_plan`] on values already refreshed into the
/// plan's kernel layout — the entry the serving admission layer calls
/// after gathering a coalesced group's value buffers. One traversal
/// factors every lane; each lane then runs its own triangular solve and
/// residual against the same RHS stream the single path draws, so every
/// per-lane number except the timings is bit-identical to that lane's
/// own [`solve_with_plan`]. `factor_s` is the batch's wall time divided
/// by `k` — the amortized per-request cost that makes batching visible
/// in the report.
pub fn solve_refreshed_batch(
    plan: &SymbolicFactorization,
    cfg: &SolverConfig,
    valss: &[&[f64]],
) -> Vec<Result<SolveReport, FactorError>> {
    let k = valss.len();
    if k == 0 {
        return Vec::new();
    }
    let t_f = Timer::start();
    let mut factors = factorize_refreshed_batch(plan, valss);
    let mut factor_s = t_f.elapsed_s() / k as f64;

    // same RHS stream as `solve_ordered` / `solve_with_plan`, per lane
    let n = plan.n;
    let mut rng = Rng::new(cfg.seed);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut xs: Vec<Option<Vec<f64>>> = vec![None; k];
    let mut solve_s = vec![f64::INFINITY; k];
    let mut time_solves = |factors: &[Result<LdlFactor, FactorError>],
                           xs: &mut Vec<Option<Vec<f64>>>,
                           solve_s: &mut Vec<f64>| {
        for (l, f) in factors.iter().enumerate() {
            if let Ok(f) = f {
                let t_s = Timer::start();
                xs[l] = Some(f.solve(&b));
                solve_s[l] = solve_s[l].min(t_s.elapsed_s());
            }
        }
    };
    time_solves(&factors, &mut xs, &mut solve_s);
    for _ in 1..cfg.measure_repeats.max(1) {
        let t_f = Timer::start();
        factors = factorize_refreshed_batch(plan, valss);
        factor_s = factor_s.min(t_f.elapsed_s() / k as f64);
        time_solves(&factors, &mut xs, &mut solve_s);
    }

    factors
        .into_iter()
        .enumerate()
        .map(|(l, r)| {
            r.map(|f| {
                let x = xs[l].as_ref().expect("factored lanes were solved");
                SolveReport {
                    reorder_s: 0.0,
                    analyze_s: 0.0,
                    factor_s,
                    solve_s: solve_s[l],
                    fill: f.fill(),
                    flops: f.flops,
                    max_col: plan.cost.max_col,
                    estimated: false,
                    residual: plan.residual(valss[l], x, &b),
                }
            })
        })
        .collect()
}

/// Batched [`solve_with_plan`]: refresh every matrix, factor all of them
/// in one traversal, solve and report per lane. Capped plans return the
/// same rate-model estimate the single path produces, once per lane.
pub fn solve_with_plan_batch(
    mats: &[&CsrMatrix],
    plan: &SymbolicFactorization,
    cfg: &SolverConfig,
    wss: &mut [NumericWorkspace],
) -> Vec<Result<SolveReport, FactorError>> {
    assert_eq!(mats.len(), wss.len(), "one workspace per batched matrix");
    if plan.capped {
        let rate = calibrated_flop_rate();
        let cost = plan.cost;
        let estimate = SolveReport {
            reorder_s: 0.0,
            analyze_s: 0.0,
            factor_s: cost.flops / rate,
            solve_s: 4.0 * cost.fill as f64 / rate,
            fill: cost.fill,
            flops: cost.flops,
            max_col: cost.max_col,
            estimated: true,
            residual: 0.0,
        };
        return mats.iter().map(|_| Ok(estimate)).collect();
    }
    for (a, ws) in mats.iter().zip(wss.iter_mut()) {
        plan.refresh_values(a, ws);
    }
    let valss: Vec<&[f64]> = wss.iter().map(|w| w.vals.as_slice()).collect();
    solve_refreshed_batch(plan, cfg, &valss)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reorder::ReorderAlgorithm;
    use crate::solver::{analyze_with, factorize_with, solve_ordered};
    use crate::sparse::CooMatrix;

    fn mesh(nx: usize, ny: usize) -> CsrMatrix {
        crate::collection::generators::grid2d(nx, ny)
    }

    /// An asymmetric matrix with one-sided entries and a partial
    /// diagonal: exercises every branch of the value map.
    fn lopsided() -> CsrMatrix {
        let mut m = CooMatrix::new(6, 6);
        m.push(0, 0, 3.0);
        m.push(0, 1, 2.0);
        m.push(1, 0, -1.0); // (0,1)/(1,0): both directions stored
        m.push(1, 3, 0.5); // one-sided
        m.push(2, 4, -2.5); // one-sided, row 2 has no diagonal
        m.push(3, 3, 1.0);
        m.push(4, 5, 4.0);
        m.push(5, 2, 0.25);
        m.to_csr()
    }

    fn mode_cfg(mode: FactorMode) -> SolverConfig {
        SolverConfig {
            factor: FactorConfig {
                mode,
                parallel_flop_min: 0.0,
                ..FactorConfig::default()
            },
            ..SolverConfig::default()
        }
    }

    #[test]
    fn refresh_matches_prepare_and_permute_bitwise() {
        for raw in [mesh(9, 7), lopsided()] {
            for mode in [FactorMode::Scalar, FactorMode::Supernodal] {
                let cfg = mode_cfg(mode);
                let spd = prepare(&raw, &cfg);
                let perm = Arc::new(ReorderAlgorithm::Amd.compute(&spd, 3));
                let plan = plan_solve(&raw, perm.clone(), &cfg);
                let mut ws = NumericWorkspace::new();
                plan.vals.as_ref().unwrap().refresh(&raw, &mut ws.vals);
                let pa = perm.apply(&spd);
                match mode {
                    FactorMode::Scalar => assert_eq!(ws.vals, pa.data),
                    _ => {
                        let sn = plan.supernodal().unwrap();
                        let bx: Vec<f64> =
                            sn.b_from.iter().map(|&s| pa.data[s]).collect();
                        assert_eq!(ws.vals, bx);
                    }
                }
            }
        }
    }

    #[test]
    fn plan_factor_is_bit_identical_to_scratch_factor() {
        let raw = mesh(11, 8);
        for mode in [
            FactorMode::Scalar,
            FactorMode::Supernodal,
            FactorMode::SupernodalParallel,
        ] {
            let cfg = mode_cfg(mode);
            let spd = prepare(&raw, &cfg);
            let perm = Arc::new(ReorderAlgorithm::Rcm.compute(&spd, 7));
            let pa = perm.apply(&spd);
            let an = analyze_with(&pa, &cfg.factor);
            let reference = factorize_with(&pa, &an, &cfg.factor).unwrap();

            let plan = plan_solve(&raw, perm, &cfg);
            let mut ws = NumericWorkspace::new();
            let f = factorize_with_plan(&raw, &plan, &mut ws).unwrap();
            assert_eq!(f.lp, reference.lp, "{mode:?}");
            assert_eq!(f.li, reference.li, "{mode:?}");
            assert_eq!(f.lx, reference.lx, "{mode:?}");
            assert_eq!(f.d, reference.d, "{mode:?}");
            assert_eq!(f.fill(), reference.fill());
        }
    }

    #[test]
    fn solve_with_plan_matches_solve_ordered() {
        let raw = mesh(10, 10);
        let cfg = SolverConfig::default();
        let spd = prepare(&raw, &cfg);
        let perm = Arc::new(ReorderAlgorithm::Amd.compute(&spd, 1));
        let reference = solve_ordered(&spd, &perm, &cfg).unwrap();
        let plan = plan_solve(&raw, perm, &cfg);
        let mut ws = NumericWorkspace::new();
        let r = solve_with_plan(&raw, &plan, &cfg, &mut ws).unwrap();
        assert!(!r.estimated);
        assert_eq!(r.fill, reference.fill);
        assert_eq!(r.flops, reference.flops);
        assert_eq!(r.max_col, reference.max_col);
        assert_eq!(r.analyze_s, 0.0, "plans pay no symbolic time");
        assert!(r.residual < 1e-8, "residual {}", r.residual);
    }

    #[test]
    fn capped_plan_estimates_like_solve_ordered() {
        let raw = mesh(10, 10);
        let cfg = SolverConfig {
            flop_cap: 10.0,
            ..SolverConfig::default()
        };
        let spd = prepare(&raw, &cfg);
        let perm = Arc::new(Permutation::identity(raw.nrows));
        let reference = solve_ordered(&spd, &perm, &cfg).unwrap();
        let plan = plan_solve(&raw, perm, &cfg);
        assert!(plan.capped);
        let mut ws = NumericWorkspace::new();
        let r = solve_with_plan(&raw, &plan, &cfg, &mut ws).unwrap();
        assert!(r.estimated);
        assert_eq!(r.fill, reference.fill);
        assert_eq!(r.flops, reference.flops);
        assert_eq!(r.residual, 0.0);
    }

    #[test]
    fn plan_is_pattern_pure_across_value_changes() {
        // one plan serves numerically different matrices with one pattern
        let raw = mesh(8, 9);
        let cfg = SolverConfig::default();
        let spd = prepare(&raw, &cfg);
        let perm = Arc::new(ReorderAlgorithm::Nd.compute(&spd, 5));
        let plan = plan_solve(&raw, perm.clone(), &cfg);

        let mut other = raw.clone();
        for v in other.data.iter_mut() {
            *v *= -1.75;
        }
        let mut ws = NumericWorkspace::new();
        let f = factorize_with_plan(&other, &plan, &mut ws).unwrap();
        let spd2 = prepare(&other, &cfg);
        let pa2 = perm.apply(&spd2);
        let an2 = analyze_with(&pa2, &cfg.factor);
        let reference = factorize_with(&pa2, &an2, &cfg.factor).unwrap();
        assert_eq!(f.lx, reference.lx);
        assert_eq!(f.d, reference.d);
    }

    #[test]
    fn batched_plan_factor_matches_single_requests_per_lane() {
        // k = 3 (chunked 2 + 1) across every factor mode: each lane of
        // the batch must equal its own single-request factorization
        let raw = mesh(9, 8);
        for mode in [
            FactorMode::Scalar,
            FactorMode::Supernodal,
            FactorMode::SupernodalParallel,
        ] {
            let cfg = mode_cfg(mode);
            let spd = prepare(&raw, &cfg);
            let perm = Arc::new(ReorderAlgorithm::Amd.compute(&spd, 3));
            let plan = plan_solve(&raw, perm, &cfg);
            let mats: Vec<CsrMatrix> = (0..3)
                .map(|l| {
                    let mut m = raw.clone();
                    for v in m.data.iter_mut() {
                        *v *= 1.0 + 0.5 * l as f64;
                    }
                    m
                })
                .collect();
            let refs: Vec<&CsrMatrix> = mats.iter().collect();
            let mut wss: Vec<NumericWorkspace> =
                (0..3).map(|_| NumericWorkspace::new()).collect();
            let batch = factorize_with_plan_batch(&refs, &plan, &mut wss);
            for (l, got) in batch.into_iter().enumerate() {
                let got = got.unwrap();
                let mut ws = NumericWorkspace::new();
                let single = factorize_with_plan(&mats[l], &plan, &mut ws).unwrap();
                assert_eq!(got.lx, single.lx, "{mode:?} lane {l}");
                assert_eq!(got.d, single.d, "{mode:?} lane {l}");
            }
        }
    }

    #[test]
    fn batched_solve_reports_match_single_requests() {
        let raw = mesh(8, 8);
        let cfg = SolverConfig::default();
        let spd = prepare(&raw, &cfg);
        let perm = Arc::new(ReorderAlgorithm::Rcm.compute(&spd, 2));
        let plan = plan_solve(&raw, perm, &cfg);
        let refs: Vec<&CsrMatrix> = vec![&raw; 4];
        let mut wss: Vec<NumericWorkspace> =
            (0..4).map(|_| NumericWorkspace::new()).collect();
        let reports = solve_with_plan_batch(&refs, &plan, &cfg, &mut wss);
        let mut ws = NumericWorkspace::new();
        let single = solve_with_plan(&raw, &plan, &cfg, &mut ws).unwrap();
        assert_eq!(reports.len(), 4);
        for r in reports {
            let r = r.unwrap();
            assert!(!r.estimated);
            assert_eq!(r.fill, single.fill);
            assert_eq!(r.flops, single.flops);
            assert_eq!(r.residual, single.residual, "lanes must solve identically");
            assert_eq!(r.analyze_s, 0.0);
        }
    }

    #[test]
    fn capped_plan_batches_like_singles() {
        let raw = mesh(10, 10);
        let cfg = SolverConfig {
            flop_cap: 10.0,
            ..SolverConfig::default()
        };
        let plan = plan_solve(&raw, Arc::new(Permutation::identity(raw.nrows)), &cfg);
        assert!(plan.capped);
        let refs: Vec<&CsrMatrix> = vec![&raw; 2];
        let mut wss: Vec<NumericWorkspace> =
            (0..2).map(|_| NumericWorkspace::new()).collect();
        for r in solve_with_plan_batch(&refs, &plan, &cfg, &mut wss) {
            let r = r.unwrap();
            assert!(r.estimated);
            assert_eq!(r.fill, plan.cost.fill);
        }
    }

    #[test]
    fn tiny_matrices_plan_cleanly() {
        for n in [0usize, 1, 2] {
            let mut coo = CooMatrix::new(n, n);
            for i in 0..n {
                coo.push(i, i, 2.0);
            }
            let raw = coo.to_csr();
            let cfg = SolverConfig::default();
            let plan = plan_solve(&raw, Arc::new(Permutation::identity(n)), &cfg);
            let mut ws = NumericWorkspace::new();
            let r = solve_with_plan(&raw, &plan, &cfg, &mut ws).unwrap();
            assert_eq!(r.fill, n as u64);
        }
    }

    /// `a` with one extra stored entry at `(i, j)`.
    fn with_extra_entry(a: &CsrMatrix, i: usize, j: usize, v: f64) -> CsrMatrix {
        let mut coo = CooMatrix::new(a.nrows, a.ncols);
        for r in 0..a.nrows {
            for (k, &c) in a.row_indices(r).iter().enumerate() {
                coo.push(r, c, a.row_data(r)[k]);
            }
        }
        coo.push(i, j, v);
        coo.to_csr()
    }

    #[test]
    fn repair_matches_scratch_plan_under_frozen_perm() {
        let raw = mesh(9, 8);
        for mode in [
            FactorMode::Scalar,
            FactorMode::Supernodal,
            FactorMode::SupernodalParallel,
        ] {
            let cfg = mode_cfg(mode);
            let spd = prepare(&raw, &cfg);
            let perm = Arc::new(ReorderAlgorithm::Amd.compute(&spd, 3));
            let donor = plan_solve(&raw, perm.clone(), &cfg);
            let drifted = with_extra_entry(&raw, 0, 5, -0.25);
            let diff = donor.diff_against(&drifted).unwrap();
            assert_eq!(diff.len(), 1, "one inserted coordinate");
            let rep = donor
                .repair(&drifted, &diff, &cfg, &RepairConfig::default())
                .expect("small drift must repair");
            assert!(Arc::ptr_eq(&rep.perm, &donor.perm), "ordering not frozen");

            let scratch = plan_solve(&drifted, perm.clone(), &cfg);
            assert_eq!(rep.cost, scratch.cost, "{mode:?}: symbolic cost diverged");
            let (mut ws_r, mut ws_s) = (NumericWorkspace::new(), NumericWorkspace::new());
            let fr = factorize_with_plan(&drifted, &rep, &mut ws_r).unwrap();
            let fs = factorize_with_plan(&drifted, &scratch, &mut ws_s).unwrap();
            assert_eq!(ws_r.vals, ws_s.vals, "{mode:?}: value refresh diverged");
            assert_eq!(fr.lx, fs.lx, "{mode:?}");
            assert_eq!(fr.d, fs.d, "{mode:?}");
            assert_eq!(fr.fill(), fs.fill(), "{mode:?}");
        }
    }

    #[test]
    fn repair_fast_path_reuses_symbolic_arcs_when_spd_pattern_survives() {
        // (1,3) is stored one-sided in `lopsided()`: adding (3,1) changes
        // the raw pattern but not the symmetrized union, so the repair
        // fast path must adopt the donor's symbolic structures verbatim
        let raw = lopsided();
        let cfg = mode_cfg(FactorMode::Supernodal);
        let spd = prepare(&raw, &cfg);
        let perm = Arc::new(ReorderAlgorithm::Amd.compute(&spd, 3));
        let donor = plan_solve(&raw, perm.clone(), &cfg);
        let drifted = with_extra_entry(&raw, 3, 1, 9.0);
        let diff = donor.diff_against(&drifted).unwrap();
        assert_eq!(diff.len(), 1);
        let rcfg = RepairConfig {
            max_drift: 0.5, // the tiny fixture needs a loose budget
            ..RepairConfig::default()
        };
        let rep = donor.repair(&drifted, &diff, &cfg, &rcfg).unwrap();
        let (dsn, rsn) = (donor.supernodal().unwrap(), rep.supernodal().unwrap());
        assert!(Arc::ptr_eq(&dsn.post, &rsn.post), "postorder not shared");
        assert!(Arc::ptr_eq(&dsn.lp, &rsn.lp), "factor pointers not shared");
        assert!(Arc::ptr_eq(&dsn.li, &rsn.li), "factor pattern not shared");

        let scratch = plan_solve(&drifted, perm, &cfg);
        let (mut ws_r, mut ws_s) = (NumericWorkspace::new(), NumericWorkspace::new());
        let fr = factorize_with_plan(&drifted, &rep, &mut ws_r).unwrap();
        let fs = factorize_with_plan(&drifted, &scratch, &mut ws_s).unwrap();
        assert_eq!(ws_r.vals, ws_s.vals);
        assert_eq!(fr.lx, fs.lx);
        assert_eq!(fr.d, fs.d);
    }

    #[test]
    fn repair_refuses_oversize_drift_config_mismatch_and_capped_donors() {
        let raw = mesh(9, 8);
        let cfg = SolverConfig::default();
        let spd = prepare(&raw, &cfg);
        let perm = Arc::new(ReorderAlgorithm::Amd.compute(&spd, 3));
        let donor = plan_solve(&raw, perm.clone(), &cfg);
        let drifted = with_extra_entry(&raw, 0, 5, -0.25);
        let diff = donor.diff_against(&drifted).unwrap();

        // zero drift budget: any edit is past the threshold
        let strict = RepairConfig {
            max_drift: 0.0,
            ..RepairConfig::default()
        };
        assert!(donor.repair(&drifted, &diff, &cfg, &strict).is_none());

        // planned under different knobs
        let other_cfg = SolverConfig {
            diag_boost: 3.0,
            ..SolverConfig::default()
        };
        assert!(donor
            .repair(&drifted, &diff, &other_cfg, &RepairConfig::default())
            .is_none());

        // capped donors retain no repair state
        let capped_cfg = SolverConfig {
            flop_cap: 10.0,
            ..SolverConfig::default()
        };
        let capped = plan_solve(&raw, perm, &capped_cfg);
        assert!(capped.capped);
        assert!(capped.raw_pattern().is_none());
        assert!(capped.diff_against(&drifted).is_none());
        assert!(capped
            .repair(&drifted, &diff, &capped_cfg, &RepairConfig::default())
            .is_none());
    }
}

//! Symbolic-plan cache: `(PatternKey, algorithm, seed, config) →
//! Arc<SymbolicFactorization>` — the serving path's second cache layer,
//! sitting behind the ordering cache.
//!
//! A [`SymbolicFactorization`] is a pure function of its key: the
//! *raw* matrix pattern (the value map's gather sources index raw
//! slots, so the raw fingerprint — not the symmetrized-adjacency one
//! the ordering cache uses — is the right identity), the reordering
//! algorithm and seed (they determine the permutation baked into the
//! plan), and the solver/factor knobs that shape the symbolic
//! structures ([`PlanKey::config`], a fingerprint over `diag_boost`,
//! `flop_cap`, and every [`super::FactorConfig`] field). Values never
//! enter a plan, so numerically-different matrices with one structure
//! share an entry — the factorization-in-loop workload shape.
//!
//! Mechanics (bounded shards, LRU-ish recency eviction, lock-free
//! hit/miss/insert/evict counters, compute-outside-the-lock misses) are
//! the shared [`ShardedCache`]; the default capacity is smaller than the
//! ordering cache's because a plan holds the O(nnz(L)) factor pattern,
//! not an O(n) permutation.
//!
//! **The near-match repair tier.** A drifting pattern (Newton steps,
//! adaptive meshes) misses the exact key on every step even though a
//! near-identical plan is resident. [`PlanCache::get_repair_or_compute`]
//! therefore runs a three-tier lookup — **exact hit → near-match repair
//! → cold miss**: on a miss, the elected leader consults a small MRU
//! index of recently planned keys sharing this key's
//! `(n, algorithm, seed, config)` family ([`NearKey`]), diffs the
//! incoming pattern against each resident donor's base pattern
//! ([`SymbolicFactorization::diff_against`]), and asks the closest donor
//! to [`SymbolicFactorization::repair`] itself before falling back to
//! the cold compute. Repairs and refused repairs are counted
//! (`repairs` / `repair_fallbacks` in [`CacheStats`]) so a silent slide
//! back to cold planning is visible in the serving stats. The tier
//! lives entirely inside the leader's compute closure, so the in-flight
//! dedup story is unchanged: a stampede on a drifted pattern costs one
//! repair (or one cold plan), never k.
//!
//! **The quarantine circuit breaker.** A `(pattern, algorithm)` whose
//! downstream compute keeps failing (reorderer panic, zero pivot under
//! that ordering) would otherwise be retried on every arrival — each
//! retry paying the full failure cost before falling back. The serving
//! engine therefore reports failed attempts via
//! [`PlanCache::report_failure`]; once a key accrues
//! [`QuarantineConfig::strikes`] failures it is tombstoned for
//! [`QuarantineConfig::ttl`], and [`PlanCache::quarantined`] tells the
//! engine to route *around* the key (straight to its fallback chain)
//! without attempting the doomed compute. Expired tombstones are removed
//! on the next probe — the key is re-admitted with a fresh strike
//! budget, so a transient failure mode (bad value set, since-fixed
//! input) does not blacklist a pattern forever. Trips and skips are
//! counted (`quarantined` / `quarantine_skips` in [`CacheStats`]).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::plan::{RepairConfig, SymbolicFactorization};
use super::SolverConfig;
use crate::reorder::ReorderAlgorithm;
use crate::sparse::{CsrMatrix, PatternKey};
use crate::util::cache::ShardedCache;

pub use crate::util::cache::{CacheConfig, CacheStats, Fetch};

/// Cache identity of one solve plan. Build through [`PlanKey::of`] so
/// the keying policy (raw-pattern fingerprint + config fingerprint)
/// lives in one place.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Fingerprint of the *raw* matrix pattern.
    pub pattern: PatternKey,
    pub algorithm: ReorderAlgorithm,
    /// Reorder seed (the permutation is a function of it).
    pub seed: u64,
    /// [`SolverConfig::plan_fingerprint`] of the planning knobs.
    pub config: u64,
}

impl PlanKey {
    /// The canonical key for planning `a` under `algorithm` with `cfg`.
    pub fn of(
        a: &CsrMatrix,
        algorithm: ReorderAlgorithm,
        seed: u64,
        cfg: &SolverConfig,
    ) -> PlanKey {
        PlanKey {
            pattern: PatternKey::of(a),
            algorithm,
            seed,
            config: cfg.plan_fingerprint(),
        }
    }
}

/// The plan-family identity used by the near-match repair tier: every
/// field of [`PlanKey`] *except* the exact pattern fingerprint, plus the
/// matrix order. Two keys in one family describe "the same problem with
/// a (possibly) drifted pattern" — only same-family residents are
/// considered as repair donors, because a repaired plan must keep the
/// donor's permutation, algorithm, seed, and planning knobs to stay
/// bit-identical with a from-scratch plan under that permutation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct NearKey {
    n: usize,
    algorithm: ReorderAlgorithm,
    seed: u64,
    config: u64,
}

impl NearKey {
    fn of(key: &PlanKey) -> NearKey {
        NearKey {
            n: key.pattern.n,
            algorithm: key.algorithm,
            seed: key.seed,
            config: key.config,
        }
    }
}

/// Circuit-breaker knobs for the quarantine tier (module docs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuarantineConfig {
    /// Failures a key may accrue before it is tombstoned.
    pub strikes: u32,
    /// Tombstone lifetime; after this the key is re-admitted with a
    /// fresh strike budget.
    pub ttl: Duration,
}

impl Default for QuarantineConfig {
    fn default() -> Self {
        QuarantineConfig {
            strikes: 3,
            ttl: Duration::from_millis(250),
        }
    }
}

/// Failure ledger for one key: strikes accrued, and — once the budget
/// is exhausted — the instant the tombstone lapses.
#[derive(Clone, Copy, Debug)]
struct Tombstone {
    strikes: u32,
    until: Option<Instant>,
}

/// Per-family MRU ring depth of the near-match index. Drifting
/// workloads revisit the last few steps' patterns; deeper history only
/// adds donors whose drift is larger (and therefore never the best
/// candidate).
const NEAR_RING: usize = 3;

/// Bounded, sharded plan cache (a [`ShardedCache`] instantiation — see
/// the module docs for keying, `util::cache` for mechanics) plus the
/// near-match repair tier (module docs).
pub struct PlanCache {
    inner: ShardedCache<PlanKey, SymbolicFactorization>,
    /// `family → MRU ring of recently planned keys` (≤ [`NEAR_RING`]
    /// each). Keys may outlive their cache entry after eviction; stale
    /// ones resolve to nothing at donor-lookup time and are harmless.
    near: Mutex<HashMap<NearKey, Vec<PlanKey>>>,
    repairs: AtomicU64,
    repair_fallbacks: AtomicU64,
    /// Quarantine circuit breaker (module docs): failure strikes and
    /// active tombstones per key. Tiny — only keys that have actually
    /// failed appear, and expired tombstones are reaped on probe.
    quarantine: Mutex<HashMap<PlanKey, Tombstone>>,
    quarantine_cfg: QuarantineConfig,
    quarantined: AtomicU64,
    quarantine_skips: AtomicU64,
}

impl PlanCache {
    pub fn new(cfg: CacheConfig) -> Self {
        Self::with_quarantine(cfg, QuarantineConfig::default())
    }

    /// A cache with explicit circuit-breaker knobs (tests and the
    /// serving engine's `ServingConfig::quarantine` override).
    pub fn with_quarantine(cfg: CacheConfig, quarantine: QuarantineConfig) -> Self {
        PlanCache {
            inner: ShardedCache::new(cfg),
            near: Mutex::new(HashMap::new()),
            repairs: AtomicU64::new(0),
            repair_fallbacks: AtomicU64::new(0),
            quarantine: Mutex::new(HashMap::new()),
            quarantine_cfg: quarantine,
            quarantined: AtomicU64::new(0),
            quarantine_skips: AtomicU64::new(0),
        }
    }

    /// Default sizing: plans are O(fill)-sized artifacts, so the bound
    /// is an order of magnitude tighter than the ordering cache's.
    pub fn default_config() -> CacheConfig {
        CacheConfig {
            capacity: 64,
            shards: 8,
        }
    }

    pub fn with_default_config() -> Self {
        Self::new(Self::default_config())
    }

    /// Effective capacity (`shards * per_shard`, ≤ the configured one).
    pub fn capacity(&self) -> usize {
        self.inner.capacity()
    }

    /// Resident entries (sums shard sizes; momentary under concurrency).
    pub fn len(&self) -> usize {
        self.inner.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    /// Counted lookup: `Some` stamps recency and counts a hit, `None`
    /// counts a miss.
    pub fn get(&self, key: &PlanKey) -> Option<Arc<SymbolicFactorization>> {
        self.inner.get(key)
    }

    /// Uncounted residency probe (no hit/miss accounting, no recency
    /// stamp) — the warm/cold question the online learner's exploration
    /// gate asks on every request.
    pub fn contains(&self, key: &PlanKey) -> bool {
        self.inner.contains(key)
    }

    /// Idempotent insert (see `util::cache`): the resident entry wins.
    /// Inserted keys join the near-match index so they can serve as
    /// repair donors.
    pub fn insert(
        &self,
        key: PlanKey,
        plan: Arc<SymbolicFactorization>,
    ) -> Arc<SymbolicFactorization> {
        let resident = self.inner.insert(key, plan);
        self.register_near(key);
        resident
    }

    /// One counted lookup; on miss, plan *outside* every lock and
    /// insert — with in-flight dedup: concurrent misses for one key
    /// elect a single leader that runs the (expensive) symbolic
    /// analysis while every other caller parks on the slot and adopts
    /// the leader's `Arc` ([`Fetch::Coalesced`]). A cold-path stampede
    /// on one pattern therefore costs exactly one reorder+plan.
    pub fn get_or_compute(
        &self,
        key: PlanKey,
        compute: impl FnOnce() -> SymbolicFactorization,
    ) -> (Arc<SymbolicFactorization>, Fetch) {
        let (plan, fetch) = self.inner.get_or_compute(key, compute);
        if fetch == Fetch::Led {
            self.register_near(key);
        }
        (plan, fetch)
    }

    /// Three-tier lookup: **exact hit → near-match repair → cold miss**
    /// (module docs). Same dedup contract as [`Self::get_or_compute`] —
    /// the repair attempt runs inside the elected leader's compute
    /// closure, so a stampede on a drifted pattern costs one repair (or
    /// one cold plan). Returns the plan, the fetch outcome, and whether
    /// *this call's* leader resolved the miss by repairing a near-match
    /// (always `false` for hits, coalesced waiters, and cold computes).
    ///
    /// `a` must be the matrix `key` was derived from; `cfg` the solver
    /// config behind `key.config`. Repair eligibility and the
    /// bit-identity contract are [`SymbolicFactorization::repair`]'s.
    pub fn get_repair_or_compute(
        &self,
        key: PlanKey,
        a: &CsrMatrix,
        cfg: &SolverConfig,
        rcfg: &RepairConfig,
        compute: impl FnOnce() -> SymbolicFactorization,
    ) -> (Arc<SymbolicFactorization>, Fetch, bool) {
        let mut repaired = false;
        let (plan, fetch) = self.inner.get_or_compute(key, || {
            match self.try_repair(&key, a, cfg, rcfg) {
                Some(plan) => {
                    repaired = true;
                    plan
                }
                None => compute(),
            }
        });
        if fetch == Fetch::Led {
            self.register_near(key);
        }
        (plan, fetch, repaired)
    }

    /// The near-match tier body (leader-only): resolve this key's
    /// family ring to resident donors, diff each donor's base pattern
    /// against `a`, and ask the structurally closest one to repair.
    /// Counts one repair on success; one fallback if at least one
    /// diffable donor existed but repair was refused (the "no silent
    /// fallback" counter). An empty/cold family counts nothing — that
    /// is a genuine cold miss, not a failed repair.
    fn try_repair(
        &self,
        key: &PlanKey,
        a: &CsrMatrix,
        cfg: &SolverConfig,
        rcfg: &RepairConfig,
    ) -> Option<SymbolicFactorization> {
        let ring: Vec<PlanKey> = {
            let near = self.near.lock().unwrap();
            match near.get(&NearKey::of(key)) {
                Some(ring) => ring.clone(),
                None => return None,
            }
        };
        let mut best: Option<(Arc<SymbolicFactorization>, crate::sparse::PatternDiff)> = None;
        for ck in ring {
            if ck == *key {
                continue; // racing leader already planned it; peek below would hit anyway
            }
            // peek: uncounted + recency-neutral — a donor probe must not
            // distort hit/miss stats or keep stale donors artificially warm
            let Some(donor) = self.inner.peek(&ck) else {
                continue; // evicted since registration
            };
            let Some(diff) = donor.diff_against(a) else {
                continue; // capped donor (no retained pattern) or order mismatch
            };
            if best.as_ref().map_or(true, |(_, b)| diff.len() < b.len()) {
                best = Some((donor, diff));
            }
        }
        let (donor, diff) = best?;
        match donor.repair(a, &diff, cfg, rcfg) {
            Some(plan) => {
                self.repairs.fetch_add(1, Ordering::Relaxed);
                Some(plan)
            }
            None => {
                self.repair_fallbacks.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// MRU-register `key` in its family ring (dedup, front-insert,
    /// truncate to [`NEAR_RING`]).
    fn register_near(&self, key: PlanKey) {
        let mut near = self.near.lock().unwrap();
        let ring = near.entry(NearKey::of(&key)).or_default();
        ring.retain(|k| *k != key);
        ring.insert(0, key);
        ring.truncate(NEAR_RING);
    }

    /// Record one failed compute attempt against `key` (reorderer
    /// panic, numeric failure under that ordering). Returns `true` when
    /// *this* strike exhausted the budget and tombstoned the key — the
    /// trip edge, counted once per quarantine event.
    pub fn report_failure(&self, key: &PlanKey) -> bool {
        let mut q = self.quarantine.lock().expect("quarantine ledger poisoned");
        let t = q.entry(*key).or_insert(Tombstone {
            strikes: 0,
            until: None,
        });
        t.strikes += 1;
        if t.until.is_none() && t.strikes >= self.quarantine_cfg.strikes {
            t.until = Some(Instant::now() + self.quarantine_cfg.ttl);
            self.quarantined.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        false
    }

    /// Is `key` currently tombstoned? `true` counts one quarantine
    /// skip (the caller is about to route around the key), so call this
    /// once per routing decision. A lapsed tombstone is removed here —
    /// the key re-enters with a fresh strike budget.
    pub fn quarantined(&self, key: &PlanKey) -> bool {
        let mut q = self.quarantine.lock().expect("quarantine ledger poisoned");
        let Some(t) = q.get(key) else {
            return false;
        };
        match t.until {
            Some(until) if Instant::now() >= until => {
                q.remove(key); // TTL lapsed: re-admit, clean slate
                false
            }
            Some(_) => {
                self.quarantine_skips.fetch_add(1, Ordering::Relaxed);
                true
            }
            None => false, // strikes accrued but budget not exhausted
        }
    }

    pub fn stats(&self) -> CacheStats {
        let mut s = self.inner.stats();
        s.repairs = self.repairs.load(Ordering::Relaxed);
        s.repair_fallbacks = self.repair_fallbacks.load(Ordering::Relaxed);
        s.quarantined = self.quarantined.load(Ordering::Relaxed);
        s.quarantine_skips = self.quarantine_skips.load(Ordering::Relaxed);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reorder::Permutation;
    use crate::solver::plan::{factorize_with_plan, plan_solve, NumericWorkspace};

    fn mesh(nx: usize, ny: usize) -> CsrMatrix {
        crate::collection::generators::grid2d(nx, ny)
    }

    #[test]
    fn keys_separate_pattern_algorithm_seed_and_config() {
        let (a, b) = (mesh(5, 5), mesh(5, 6));
        let cfg = SolverConfig::default();
        let other_cfg = SolverConfig {
            diag_boost: 3.0,
            ..SolverConfig::default()
        };
        let base = PlanKey::of(&a, ReorderAlgorithm::Amd, 1, &cfg);
        assert_eq!(base, PlanKey::of(&a, ReorderAlgorithm::Amd, 1, &cfg));
        assert_ne!(base, PlanKey::of(&b, ReorderAlgorithm::Amd, 1, &cfg));
        assert_ne!(base, PlanKey::of(&a, ReorderAlgorithm::Rcm, 1, &cfg));
        assert_ne!(base, PlanKey::of(&a, ReorderAlgorithm::Amd, 2, &cfg));
        assert_ne!(base, PlanKey::of(&a, ReorderAlgorithm::Amd, 1, &other_cfg));
    }

    #[test]
    fn cached_plan_replays_for_structurally_equal_matrices() {
        let a = mesh(7, 6);
        let cfg = SolverConfig::default();
        let cache = PlanCache::with_default_config();
        let key = PlanKey::of(&a, ReorderAlgorithm::Natural, 0, &cfg);
        let n = a.nrows;
        let (plan, fetch) = cache.get_or_compute(key, || {
            plan_solve(&a, std::sync::Arc::new(Permutation::identity(n)), &cfg)
        });
        assert_eq!(fetch, Fetch::Led);

        // same pattern, different values: key matches, plan is reused
        let mut other = a.clone();
        for v in other.data.iter_mut() {
            *v *= 2.5;
        }
        let key2 = PlanKey::of(&other, ReorderAlgorithm::Natural, 0, &cfg);
        assert_eq!(key, key2);
        let (plan2, f2) = cache.get_or_compute(key2, || unreachable!("must hit"));
        assert!(f2.is_hit());
        assert!(Arc::ptr_eq(&plan, &plan2));
        let mut ws = NumericWorkspace::new();
        let f = factorize_with_plan(&other, &plan2, &mut ws).unwrap();
        assert_eq!(f.fill(), plan.cost.fill);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    fn with_extra(a: &CsrMatrix, i: usize, j: usize, v: f64) -> CsrMatrix {
        let mut coo = crate::sparse::CooMatrix::new(a.nrows, a.ncols);
        for r in 0..a.nrows {
            for (k, &c) in a.row_indices(r).iter().enumerate() {
                coo.push(r, c, a.row_data(r)[k]);
            }
        }
        coo.push(i, j, v);
        coo.to_csr()
    }

    #[test]
    fn repair_tier_repairs_near_matches_and_counts_fallbacks() {
        let a = mesh(7, 6);
        let cfg = SolverConfig::default();
        let rcfg = RepairConfig::default();
        let cache = PlanCache::with_default_config();
        let perm = Arc::new(Permutation::identity(a.nrows));

        // cold miss: plans from scratch and registers the family ring
        let key = PlanKey::of(&a, ReorderAlgorithm::Natural, 0, &cfg);
        let (_, fetch, repaired) = cache.get_repair_or_compute(key, &a, &cfg, &rcfg, || {
            plan_solve(&a, perm.clone(), &cfg)
        });
        assert_eq!((fetch, repaired), (Fetch::Led, false));

        // one-edge drift: the near-match tier must repair, not cold-plan
        let drifted = with_extra(&a, 0, 5, -0.125);
        let key2 = PlanKey::of(&drifted, ReorderAlgorithm::Natural, 0, &cfg);
        assert_ne!(key, key2);
        let (plan2, f2, r2) = cache.get_repair_or_compute(key2, &drifted, &cfg, &rcfg, || {
            unreachable!("drift within budget must repair, not cold-plan")
        });
        assert_eq!((f2, r2), (Fetch::Led, true));
        let scratch = plan_solve(&drifted, perm.clone(), &cfg);
        assert_eq!(plan2.cost, scratch.cost);

        // replaying the drifted key is a plain exact hit, no repair
        let (plan3, f3, r3) =
            cache.get_repair_or_compute(key2, &drifted, &cfg, &rcfg, || unreachable!("must hit"));
        assert!(f3.is_hit() && !r3);
        assert!(Arc::ptr_eq(&plan2, &plan3));

        // zero drift budget: donors exist but repair refuses → counted
        // fallback, cold compute runs
        let strict = RepairConfig {
            max_drift: 0.0,
            ..RepairConfig::default()
        };
        let drifted2 = with_extra(&a, 1, 4, 0.25);
        let key3 = PlanKey::of(&drifted2, ReorderAlgorithm::Natural, 0, &cfg);
        let (_, f4, r4) = cache.get_repair_or_compute(key3, &drifted2, &cfg, &strict, || {
            plan_solve(&drifted2, perm.clone(), &cfg)
        });
        assert_eq!((f4, r4), (Fetch::Led, false));

        let s = cache.stats();
        assert_eq!(s.repairs, 1);
        assert_eq!(s.repair_fallbacks, 1);
        assert_eq!((s.hits, s.misses), (1, 3));
    }

    #[test]
    fn quarantine_trips_after_k_strikes_and_ttl_readmits() {
        let cache = PlanCache::with_quarantine(
            PlanCache::default_config(),
            QuarantineConfig {
                strikes: 2,
                ttl: Duration::from_millis(30),
            },
        );
        let a = mesh(5, 5);
        let key = PlanKey::of(&a, ReorderAlgorithm::Amd, 0, &SolverConfig::default());

        // below the strike budget: the key is still admissible
        assert!(!cache.report_failure(&key), "one strike must not trip");
        assert!(!cache.quarantined(&key));
        // second strike exhausts the budget — the trip edge fires once
        assert!(cache.report_failure(&key), "strike budget exhausted");
        assert!(cache.quarantined(&key), "tombstoned key must be skipped");
        assert!(cache.quarantined(&key), "skip repeats while the TTL runs");

        // TTL lapse: the tombstone is reaped and the key re-admitted
        std::thread::sleep(Duration::from_millis(40));
        assert!(!cache.quarantined(&key), "lapsed tombstone must re-admit");
        // re-admission is a clean slate: one new strike must not trip
        assert!(!cache.report_failure(&key), "strike budget must reset");
        assert!(!cache.quarantined(&key));

        let s = cache.stats();
        assert_eq!(s.quarantined, 1, "one trip event");
        assert_eq!(s.quarantine_skips, 2, "two counted skips before lapse");
    }

    #[test]
    fn healthy_keys_never_touch_the_quarantine_ledger() {
        let cache = PlanCache::with_default_config();
        let a = mesh(4, 4);
        let cfg = SolverConfig::default();
        let key = PlanKey::of(&a, ReorderAlgorithm::Rcm, 0, &cfg);
        let other = PlanKey::of(&a, ReorderAlgorithm::Nd, 0, &cfg);
        assert!(!cache.quarantined(&key));
        // strikes are per-key: failures against one key leave siblings
        // of the same pattern admissible
        cache.report_failure(&key);
        assert!(!cache.quarantined(&other));
        let s = cache.stats();
        assert_eq!((s.quarantined, s.quarantine_skips), (0, 0));
    }
}

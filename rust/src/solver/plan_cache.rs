//! Symbolic-plan cache: `(PatternKey, algorithm, seed, config) →
//! Arc<SymbolicFactorization>` — the serving path's second cache layer,
//! sitting behind the ordering cache.
//!
//! A [`SymbolicFactorization`] is a pure function of its key: the
//! *raw* matrix pattern (the value map's gather sources index raw
//! slots, so the raw fingerprint — not the symmetrized-adjacency one
//! the ordering cache uses — is the right identity), the reordering
//! algorithm and seed (they determine the permutation baked into the
//! plan), and the solver/factor knobs that shape the symbolic
//! structures ([`PlanKey::config`], a fingerprint over `diag_boost`,
//! `flop_cap`, and every [`super::FactorConfig`] field). Values never
//! enter a plan, so numerically-different matrices with one structure
//! share an entry — the factorization-in-loop workload shape.
//!
//! Mechanics (bounded shards, LRU-ish recency eviction, lock-free
//! hit/miss/insert/evict counters, compute-outside-the-lock misses) are
//! the shared [`ShardedCache`]; the default capacity is smaller than the
//! ordering cache's because a plan holds the O(nnz(L)) factor pattern,
//! not an O(n) permutation.

use std::sync::Arc;

use super::plan::SymbolicFactorization;
use super::SolverConfig;
use crate::reorder::ReorderAlgorithm;
use crate::sparse::{CsrMatrix, PatternKey};
use crate::util::cache::ShardedCache;

pub use crate::util::cache::{CacheConfig, CacheStats, Fetch};

/// Cache identity of one solve plan. Build through [`PlanKey::of`] so
/// the keying policy (raw-pattern fingerprint + config fingerprint)
/// lives in one place.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Fingerprint of the *raw* matrix pattern.
    pub pattern: PatternKey,
    pub algorithm: ReorderAlgorithm,
    /// Reorder seed (the permutation is a function of it).
    pub seed: u64,
    /// [`SolverConfig::plan_fingerprint`] of the planning knobs.
    pub config: u64,
}

impl PlanKey {
    /// The canonical key for planning `a` under `algorithm` with `cfg`.
    pub fn of(
        a: &CsrMatrix,
        algorithm: ReorderAlgorithm,
        seed: u64,
        cfg: &SolverConfig,
    ) -> PlanKey {
        PlanKey {
            pattern: PatternKey::of(a),
            algorithm,
            seed,
            config: cfg.plan_fingerprint(),
        }
    }
}

/// Bounded, sharded plan cache (a [`ShardedCache`] instantiation — see
/// the module docs for keying, `util::cache` for mechanics).
pub struct PlanCache {
    inner: ShardedCache<PlanKey, SymbolicFactorization>,
}

impl PlanCache {
    pub fn new(cfg: CacheConfig) -> Self {
        PlanCache {
            inner: ShardedCache::new(cfg),
        }
    }

    /// Default sizing: plans are O(fill)-sized artifacts, so the bound
    /// is an order of magnitude tighter than the ordering cache's.
    pub fn default_config() -> CacheConfig {
        CacheConfig {
            capacity: 64,
            shards: 8,
        }
    }

    pub fn with_default_config() -> Self {
        Self::new(Self::default_config())
    }

    /// Effective capacity (`shards * per_shard`, ≤ the configured one).
    pub fn capacity(&self) -> usize {
        self.inner.capacity()
    }

    /// Resident entries (sums shard sizes; momentary under concurrency).
    pub fn len(&self) -> usize {
        self.inner.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    /// Counted lookup: `Some` stamps recency and counts a hit, `None`
    /// counts a miss.
    pub fn get(&self, key: &PlanKey) -> Option<Arc<SymbolicFactorization>> {
        self.inner.get(key)
    }

    /// Uncounted residency probe (no hit/miss accounting, no recency
    /// stamp) — the warm/cold question the online learner's exploration
    /// gate asks on every request.
    pub fn contains(&self, key: &PlanKey) -> bool {
        self.inner.contains(key)
    }

    /// Idempotent insert (see `util::cache`): the resident entry wins.
    pub fn insert(
        &self,
        key: PlanKey,
        plan: Arc<SymbolicFactorization>,
    ) -> Arc<SymbolicFactorization> {
        self.inner.insert(key, plan)
    }

    /// One counted lookup; on miss, plan *outside* every lock and
    /// insert — with in-flight dedup: concurrent misses for one key
    /// elect a single leader that runs the (expensive) symbolic
    /// analysis while every other caller parks on the slot and adopts
    /// the leader's `Arc` ([`Fetch::Coalesced`]). A cold-path stampede
    /// on one pattern therefore costs exactly one reorder+plan.
    pub fn get_or_compute(
        &self,
        key: PlanKey,
        compute: impl FnOnce() -> SymbolicFactorization,
    ) -> (Arc<SymbolicFactorization>, Fetch) {
        self.inner.get_or_compute(key, compute)
    }

    pub fn stats(&self) -> CacheStats {
        self.inner.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reorder::Permutation;
    use crate::solver::plan::{factorize_with_plan, plan_solve, NumericWorkspace};

    fn mesh(nx: usize, ny: usize) -> CsrMatrix {
        crate::collection::generators::grid2d(nx, ny)
    }

    #[test]
    fn keys_separate_pattern_algorithm_seed_and_config() {
        let (a, b) = (mesh(5, 5), mesh(5, 6));
        let cfg = SolverConfig::default();
        let other_cfg = SolverConfig {
            diag_boost: 3.0,
            ..SolverConfig::default()
        };
        let base = PlanKey::of(&a, ReorderAlgorithm::Amd, 1, &cfg);
        assert_eq!(base, PlanKey::of(&a, ReorderAlgorithm::Amd, 1, &cfg));
        assert_ne!(base, PlanKey::of(&b, ReorderAlgorithm::Amd, 1, &cfg));
        assert_ne!(base, PlanKey::of(&a, ReorderAlgorithm::Rcm, 1, &cfg));
        assert_ne!(base, PlanKey::of(&a, ReorderAlgorithm::Amd, 2, &cfg));
        assert_ne!(base, PlanKey::of(&a, ReorderAlgorithm::Amd, 1, &other_cfg));
    }

    #[test]
    fn cached_plan_replays_for_structurally_equal_matrices() {
        let a = mesh(7, 6);
        let cfg = SolverConfig::default();
        let cache = PlanCache::with_default_config();
        let key = PlanKey::of(&a, ReorderAlgorithm::Natural, 0, &cfg);
        let n = a.nrows;
        let (plan, fetch) = cache.get_or_compute(key, || {
            plan_solve(&a, std::sync::Arc::new(Permutation::identity(n)), &cfg)
        });
        assert_eq!(fetch, Fetch::Led);

        // same pattern, different values: key matches, plan is reused
        let mut other = a.clone();
        for v in other.data.iter_mut() {
            *v *= 2.5;
        }
        let key2 = PlanKey::of(&other, ReorderAlgorithm::Natural, 0, &cfg);
        assert_eq!(key, key2);
        let (plan2, f2) = cache.get_or_compute(key2, || unreachable!("must hit"));
        assert!(f2.is_hit());
        assert!(Arc::ptr_eq(&plan, &plan2));
        let mut ws = NumericWorkspace::new();
        let f = factorize_with_plan(&other, &plan2, &mut ws).unwrap();
        assert_eq!(f.fill(), plan.cost.fill);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }
}

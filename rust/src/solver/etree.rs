//! Elimination tree and factor column counts (symbolic analysis core).
//!
//! Liu's elimination-tree algorithm with path compression, plus the
//! row-subtree walk that yields per-column factor counts in O(nnz(L))
//! time and O(n) space — enough to compute fill/flops for a candidate
//! ordering *without* allocating the factor, which is what the
//! reordering-quality metrics and the solver's flop-cap guard use.
//!
//! All functions take the symmetric adjacency pattern `(indptr, indices)`
//! of the (permuted) matrix — self-loops optional, both triangles stored.
//!
//! This is the bottom of the solver's **symbolic** side: everything here
//! is a pure function of the pattern (values never enter), which is what
//! lets [`crate::solver::plan`] freeze the outputs — tree, counts,
//! [`SymbolicCost`] — into a cached, replayable
//! [`crate::solver::SymbolicFactorization`].

/// Sentinel for "no parent" (tree root).
pub const NONE: usize = usize::MAX;

/// Elimination tree: `parent[v]` of each column, `NONE` for roots.
pub fn etree(indptr: &[usize], indices: &[usize]) -> Vec<usize> {
    let n = indptr.len() - 1;
    let mut parent = vec![NONE; n];
    let mut ancestor = vec![NONE; n];
    for i in 0..n {
        for &j in &indices[indptr[i]..indptr[i + 1]] {
            if j >= i {
                continue; // lower triangle only
            }
            // walk from j to the root of its current subtree, compressing
            let mut k = j;
            while ancestor[k] != NONE && ancestor[k] != i {
                let next = ancestor[k];
                ancestor[k] = i;
                k = next;
            }
            if ancestor[k] == NONE {
                ancestor[k] = i;
                parent[k] = i;
            }
        }
    }
    parent
}

/// Post-order of the elimination forest (children before parents).
pub fn postorder(parent: &[usize]) -> Vec<usize> {
    let n = parent.len();
    // build child lists
    let mut head = vec![NONE; n];
    let mut next = vec![NONE; n];
    // iterate in reverse so children lists come out ascending
    for v in (0..n).rev() {
        let p = parent[v];
        if p != NONE {
            next[v] = head[p];
            head[p] = v;
        }
    }
    let mut order = Vec::with_capacity(n);
    let mut stack = Vec::new();
    for root in 0..n {
        if parent[root] != NONE {
            continue;
        }
        // iterative DFS emitting post-order
        stack.push((root, false));
        while let Some((v, expanded)) = stack.pop() {
            if expanded {
                order.push(v);
                continue;
            }
            stack.push((v, true));
            let mut c = head[v];
            while c != NONE {
                stack.push((c, false));
                c = next[c];
            }
        }
    }
    order
}

/// First descendants in a postordered forest: `fd[v]` is the smallest
/// label in the subtree rooted at `v`. Only meaningful when the labels
/// themselves are a postorder (every `parent[v] > v`), which is how the
/// supernodal analysis calls it — after relabeling by [`postorder`].
///
/// Fundamental-supernode detection needs this: columns `j-1, j` can share
/// a supernode only if `fd[j] == fd[j-1]`, i.e. `j-1` is the *only* child
/// of `j` (otherwise `j` merges several subtrees and its frontal matrix
/// assembles more than one child update).
pub fn first_descendants(parent: &[usize]) -> Vec<usize> {
    let n = parent.len();
    let mut fd: Vec<usize> = (0..n).collect();
    for v in 0..n {
        let p = parent[v];
        if p != NONE {
            debug_assert!(p > v, "first_descendants needs a postordered tree");
            if fd[v] < fd[p] {
                fd[p] = fd[v];
            }
        }
    }
    fd
}

/// Factor column counts: `counts[j]` = nnz of column j of L *excluding*
/// the diagonal. Row-subtree marking walk (Liu).
pub fn col_counts(indptr: &[usize], indices: &[usize], parent: &[usize]) -> Vec<usize> {
    let n = indptr.len() - 1;
    let mut counts = vec![0usize; n];
    let mut mark = vec![NONE; n];
    for i in 0..n {
        mark[i] = i;
        for &j in &indices[indptr[i]..indptr[i + 1]] {
            if j >= i {
                continue;
            }
            let mut k = j;
            while mark[k] != i {
                mark[k] = i;
                counts[k] += 1;
                k = parent[k];
                debug_assert!(k != NONE, "walk escaped the row subtree");
            }
        }
    }
    counts
}

/// Symbolic cost summary for an ordering.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SymbolicCost {
    /// nnz(L) including the unit diagonal.
    pub fill: u64,
    /// Multiply-add count of an LDLᵀ factorization with this pattern:
    /// Σ_j c_j (c_j + 3) / 2  (c_j = offdiag count of column j).
    pub flops: f64,
    /// Maximum column count (frontal-size proxy).
    pub max_col: usize,
}

/// Fill and flops from column counts.
pub fn symbolic_cost(counts: &[usize]) -> SymbolicCost {
    let n = counts.len() as u64;
    let mut fill = n;
    let mut flops = 0f64;
    let mut max_col = 0usize;
    for &c in counts {
        fill += c as u64;
        let cf = c as f64;
        flops += cf * (cf + 3.0) / 2.0;
        max_col = max_col.max(c);
    }
    SymbolicCost {
        fill,
        flops,
        max_col,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    /// dense pattern helper: full lower+upper adjacency from edges
    fn adj(n: usize, edges: &[(usize, usize)]) -> (Vec<usize>, Vec<usize>) {
        let g = Graph::from_edges(n, edges);
        (g.indptr, g.indices)
    }

    #[test]
    fn etree_of_tridiagonal_is_path() {
        let edges: Vec<(usize, usize)> = (0..5).map(|i| (i, i + 1)).collect();
        let (ip, ix) = adj(6, &edges);
        let parent = etree(&ip, &ix);
        assert_eq!(parent, vec![1, 2, 3, 4, 5, NONE]);
    }

    #[test]
    fn etree_of_arrow_points_to_hub() {
        // arrow with hub at the LAST index: no fill, every column's parent
        // is the hub
        let n = 6;
        let edges: Vec<(usize, usize)> = (0..5).map(|i| (i, 5)).collect();
        let (ip, ix) = adj(n, &edges);
        let parent = etree(&ip, &ix);
        assert_eq!(parent, vec![5, 5, 5, 5, 5, NONE]);
    }

    #[test]
    fn col_counts_tridiagonal_no_fill() {
        let edges: Vec<(usize, usize)> = (0..7).map(|i| (i, i + 1)).collect();
        let (ip, ix) = adj(8, &edges);
        let parent = etree(&ip, &ix);
        let counts = col_counts(&ip, &ix, &parent);
        assert_eq!(counts, vec![1, 1, 1, 1, 1, 1, 1, 0]);
        let cost = symbolic_cost(&counts);
        assert_eq!(cost.fill, 8 + 7);
        assert_eq!(cost.max_col, 1);
    }

    #[test]
    fn col_counts_arrow_reversed_fills_completely() {
        // hub at index 0: eliminating the hub first makes L dense
        let n = 5;
        let edges: Vec<(usize, usize)> = (1..n).map(|i| (0, i)).collect();
        let (ip, ix) = adj(n, &edges);
        let parent = etree(&ip, &ix);
        let counts = col_counts(&ip, &ix, &parent);
        // column 0 connects to all, then the quotient is a clique
        assert_eq!(counts[0], n - 1);
        let cost = symbolic_cost(&counts);
        assert_eq!(cost.fill, (n * (n + 1) / 2) as u64);
    }

    #[test]
    fn postorder_children_before_parents() {
        let edges: Vec<(usize, usize)> = vec![(0, 2), (1, 2), (2, 4), (3, 4)];
        let (ip, ix) = adj(5, &edges);
        let parent = etree(&ip, &ix);
        let post = postorder(&parent);
        assert_eq!(post.len(), 5);
        let mut pos = vec![0; 5];
        for (k, &v) in post.iter().enumerate() {
            pos[v] = k;
        }
        for v in 0..5 {
            if parent[v] != NONE {
                assert!(pos[v] < pos[parent[v]], "{v} after parent");
            }
        }
    }

    #[test]
    fn postorder_handles_forest() {
        let (ip, ix) = adj(4, &[(0, 1), (2, 3)]);
        let parent = etree(&ip, &ix);
        let post = postorder(&parent);
        let mut sorted = post.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3]);
    }

    #[test]
    fn first_descendants_of_path_and_fork() {
        // path 0->1->2 (postordered): fd = [0, 0, 0]
        assert_eq!(first_descendants(&[1, 2, NONE]), vec![0, 0, 0]);
        // fork: 0->2, 1->2: node 2 has two children, fd[2] = 0 but
        // fd[1] = 1, so columns 1 and 2 must not share a supernode.
        assert_eq!(first_descendants(&[2, 2, NONE]), vec![0, 1, 0]);
    }

    #[test]
    fn symbolic_cost_flops_formula() {
        let counts = vec![3, 0];
        let c = symbolic_cost(&counts);
        assert_eq!(c.flops, 3.0 * 6.0 / 2.0);
        assert_eq!(c.fill, 2 + 3);
        assert_eq!(c.max_col, 3);
    }
}

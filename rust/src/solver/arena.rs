//! Per-worker front/update arenas for the multifrontal numeric phase.
//!
//! The supernodal driver used to allocate one dense frontal matrix and
//! one `m×m` update matrix **per supernode** — O(#fronts) heap round
//! trips on the hottest path in the system. A [`FrontArena`] replaces
//! all of them with three long-lived buffers per worker:
//!
//! * `front` — one dense panel buffer, sized once to the plan's peak
//!   front ([`crate::solver::SupernodalPlan::peak_front`]);
//! * `stack` — a bump stack of pending update matrices. A postorder walk
//!   consumes updates in exactly LIFO order (a supernode's children are
//!   always the most recently produced unconsumed updates — the
//!   classical multifrontal stack), so "free" is a truncate and "alloc"
//!   is a resize inside reserved capacity;
//! * `map` — the global-row → front-row scatter map.
//!
//! Arenas live in a process-wide [`ObjectPool`]: workers check one out
//! per task (RAII guard — panic unwind returns it), size it from the
//! plan's precomputed peaks, and park it warm. Steady-state serving
//! therefore factors with **zero heap allocation for fronts**: the only
//! allocator traffic is the first request per (larger-than-ever) plan,
//! observable through [`grow_events`] — the counter the benches and the
//! zero-alloc property tests assert on.
//!
//! Updates that must cross a task boundary in the pipelined schedule
//! (subtree roots and top-of-tree supernodes, see
//! [`crate::solver::supernodal`]) cannot live in a worker-local arena;
//! they travel in [`BoundaryBuf`]s — `Vec<f64>`s drawn from a second
//! process-wide pool, returned when the parent consumes them.
//!
//! The batched multi-RHS traversal needs no arena API of its own: its
//! fronts are lane-interleaved (`K` values per pattern slot), so callers
//! simply `begin` with `peak_front · K` / `stack_peak · K` elements and
//! checkout `m·m·K`-element boundary buffers. The first batch at a new
//! (plan, K) therefore grows the warm buffers once — a counted event —
//! and subsequent same-width batches are allocation-free like the
//! single-lane path.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use crate::util::pool::{ObjectPool, PoolStats, PooledObject};

/// Global count of arena/boundary backing-buffer growth events (a grow =
/// an actual heap allocation on the numeric path). Flat between two
/// factorizations ⇔ the second one was allocation-free for fronts.
static GROW_EVENTS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    /// Per-thread mirror of [`GROW_EVENTS`]: lets a test assert
    /// "this factorization allocated nothing" without racing against
    /// unrelated test threads bumping the process-wide counter.
    static TL_GROW_EVENTS: Cell<u64> = Cell::new(0);
}

fn note_grow() {
    GROW_EVENTS.fetch_add(1, Ordering::Relaxed);
    TL_GROW_EVENTS.with(|c| c.set(c.get() + 1));
}

/// Cumulative front-allocation events (arena + boundary buffer growth)
/// since process start. The serving bench derives its `warm_alloc_free`
/// flag from deltas of this counter.
pub fn grow_events() -> u64 {
    GROW_EVENTS.load(Ordering::Relaxed)
}

/// [`grow_events`] restricted to the calling thread — the race-free
/// handle the zero-alloc property tests take deltas of (a sequential
/// factorization's growths all land on the caller's thread).
pub fn thread_grow_events() -> u64 {
    TL_GROW_EVENTS.with(|c| c.get())
}

/// Counter snapshot of the arena subsystem.
#[derive(Clone, Copy, Debug, Default)]
pub struct ArenaStats {
    /// Arena-pool counters (checkouts/creates/reuses/idle).
    pub arenas: PoolStats,
    /// Boundary-buffer-pool counters.
    pub boundary: PoolStats,
    /// Backing-buffer growth events (see [`grow_events`]).
    pub grows: u64,
}

struct Pools {
    arenas: ObjectPool<FrontArena>,
    boundary: ObjectPool<Vec<f64>>,
}

fn pools() -> &'static Pools {
    static POOLS: OnceLock<Pools> = OnceLock::new();
    POOLS.get_or_init(|| {
        let idle = crate::util::pool::default_workers() + 1;
        Pools {
            arenas: ObjectPool::new(idle),
            // cross-task updates: up to ~3 live per worker while a top
            // front assembles its children
            boundary: ObjectPool::new(4 * idle),
        }
    })
}

/// Check a warm arena out of the process-wide pool (RAII: returns on
/// drop, panic unwind included). This is the DAG workers' checkout —
/// their scoped threads are born per factorization, so thread-pinned
/// storage would always be cold; the pool keeps their arenas warm across
/// factorizations instead.
pub fn checkout_arena() -> PooledObject<'static, FrontArena> {
    pools().arenas.checkout_guard(FrontArena::new)
}

/// Run `f` on the calling thread's pinned arena. The sequential numeric
/// path lives here: a long-lived serving or sweep thread re-uses one
/// private arena with no pool traffic at all, and — because the arena is
/// thread-private — a warm second factorization is *deterministically*
/// allocation-free (what the zero-alloc property tests assert through
/// [`thread_grow_events`]). Not re-entrant (the numeric phase never
/// calls back into itself).
pub fn with_serial_arena<R>(f: impl FnOnce(&mut FrontArena) -> R) -> R {
    thread_local! {
        static SERIAL_ARENA: std::cell::RefCell<FrontArena> =
            std::cell::RefCell::new(FrontArena::new());
    }
    SERIAL_ARENA.with(|a| f(&mut a.borrow_mut()))
}

/// Check a boundary update buffer out, sized to `len` elements and
/// zero-filled (harvest only writes the lower triangle; zeroing keeps
/// the never-read upper slots deterministic across reuse, exactly like
/// the arena stack's updates).
pub fn checkout_boundary(len: usize) -> BoundaryBuf {
    let mut buf = pools().boundary.checkout_guard(Vec::new);
    if buf.capacity() < len {
        note_grow();
    }
    buf.clear();
    buf.resize(len, 0.0);
    BoundaryBuf { buf }
}

/// Counters across both pools plus the growth tally.
pub fn stats() -> ArenaStats {
    let p = pools();
    ArenaStats {
        arenas: p.arenas.stats(),
        boundary: p.boundary.stats(),
        grows: grow_events(),
    }
}

/// A pooled dense update matrix crossing a task boundary (column-major
/// `m×m`, lower triangle filled). Returns to the boundary pool on drop.
pub struct BoundaryBuf {
    buf: PooledObject<'static, Vec<f64>>,
}

impl std::ops::Deref for BoundaryBuf {
    type Target = [f64];

    fn deref(&self) -> &[f64] {
        &self.buf
    }
}

impl std::ops::DerefMut for BoundaryBuf {
    fn deref_mut(&mut self) -> &mut [f64] {
        &mut self.buf
    }
}

/// Per-worker scratch for a run of fronts: the dense panel buffer, the
/// update bump stack, and the row scatter map (see the module docs).
/// Create via [`checkout_arena`]; size with [`FrontArena::begin`] once
/// per task.
#[derive(Default)]
pub struct FrontArena {
    /// Global (postordered) row → local front row. Only entries of the
    /// current front are ever read, so no reset between fronts.
    pub(crate) map: Vec<usize>,
    /// Dense frontal buffer; the active front is the `ld*ld` prefix.
    pub(crate) front: Vec<f64>,
    /// Bump stack of pending update matrices (LIFO by construction).
    pub(crate) stack: Vec<f64>,
    /// Reusable `(supernode, stack offset)` bookkeeping for the pending
    /// stack — taken by the driver for the duration of a task.
    pub(crate) pending: Vec<(usize, usize)>,
}

impl FrontArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Prepare for a task over an `n`-column matrix whose fronts need at
    /// most `front_elems` dense elements and whose update stack peaks at
    /// `stack_elems` elements (both precomputed by the symbolic plan).
    /// Grows the backing buffers only when this plan is larger than
    /// anything the arena has seen — each growth is a counted heap event;
    /// a warm arena re-begins for free.
    pub fn begin(&mut self, n: usize, front_elems: usize, stack_elems: usize) {
        if self.map.len() < n {
            note_grow();
            self.map.resize(n, 0);
        }
        if self.front.len() < front_elems {
            note_grow();
            self.front.resize(front_elems, 0.0);
        }
        if self.stack.capacity() < stack_elems {
            note_grow();
            self.stack.reserve(stack_elems - self.stack.len());
        }
        self.stack.clear();
    }

    /// Push an uninitialized (zero-filled) update of `len` elements onto
    /// the bump stack; returns its offset. Within the reserved capacity
    /// this never touches the allocator (offsets — not pointers — index
    /// the stack, so even an unexpected growth stays correct; it is
    /// merely counted).
    pub(crate) fn push_update(&mut self, len: usize) -> usize {
        let off = self.stack.len();
        if self.stack.capacity() < off + len {
            note_grow();
        }
        self.stack.resize(off + len, 0.0);
        off
    }

    /// Free every update at or above `off` (LIFO discipline).
    pub(crate) fn truncate_updates(&mut self, off: usize) {
        debug_assert!(off <= self.stack.len());
        self.stack.truncate(off);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn begin_grows_once_then_stays_warm() {
        let mut a = FrontArena::new();
        let before = thread_grow_events();
        a.begin(100, 64, 32);
        assert!(thread_grow_events() > before, "first begin must grow");
        let warm = thread_grow_events();
        for _ in 0..5 {
            a.begin(100, 64, 32);
            a.begin(50, 16, 8); // smaller plans ride the same buffers
        }
        assert_eq!(thread_grow_events(), warm, "warm begins must not allocate");
        a.begin(100, 65, 32); // larger front → one more growth
        assert_eq!(thread_grow_events(), warm + 1);
    }

    #[test]
    fn update_stack_is_lifo_and_alloc_free_within_capacity() {
        let mut a = FrontArena::new();
        a.begin(10, 4, 100);
        let warm = thread_grow_events();
        let o1 = a.push_update(30);
        let o2 = a.push_update(40);
        assert_eq!((o1, o2), (0, 30));
        a.stack[o2] = 7.0;
        a.truncate_updates(o2);
        let o3 = a.push_update(20);
        assert_eq!(o3, 30, "freed space is reused");
        assert_eq!(a.stack[o3], 0.0, "updates start zeroed");
        assert_eq!(thread_grow_events(), warm);
    }

    #[test]
    fn boundary_buffers_recycle() {
        // counters are process-global (other test threads may also be
        // checking buffers out), so assert monotonically
        let before = stats().boundary.checkouts;
        {
            let mut b = checkout_boundary(16);
            b[0] = 1.0;
        }
        let b2 = checkout_boundary(8);
        assert_eq!(b2.len(), 8);
        let s = stats();
        assert!(s.boundary.checkouts >= before + 2);
        assert_eq!(s.boundary.checkouts, s.boundary.creates + s.boundary.reuses);
    }
}

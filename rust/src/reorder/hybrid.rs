//! Hybrid orderings combining nested dissection with minimum-degree —
//! the paper's fourth category (Table 2: SCOTCH, PORD).
//!
//! * [`scotch_like`] mirrors SCOTCH's `esmumps` ordering strategy:
//!   multilevel nested dissection on the top levels, switching to
//!   (approximate) minimum degree once subgraphs fall below a threshold
//!   (SCOTCH's default "nd with amd on small domains").
//! * [`pord_like`] mirrors PORD's bottom-up/top-down blend: dissection
//!   with a larger switch threshold and a min-*fill* local ordering,
//!   which is the distinguishing heuristic of Schulze's PORD.
//!
//! Both differ from pure [`super::nd`] (tiny leaves, exact-MD local
//! ordering) and from pure AMD, giving the four label classes genuinely
//! different behaviour across matrix families.

use super::engine::Reorderer;
use super::mindeg::{min_degree_in, Variant};
use super::nd::dissection_with;
use super::workspace::Workspace;
use super::{seed_rng, Permutation, ReorderAlgorithm};
use crate::graph::Graph;
use crate::util::rng::Rng;

/// Subgraph size below which SCOTCH-like ordering switches to AMD.
const SCOTCH_SWITCH: usize = 240;

/// Subgraph size below which PORD-like ordering switches to min-fill.
const PORD_SWITCH: usize = 480;

/// SCOTCH-style hybrid: ND on top, AMD below `SCOTCH_SWITCH`.
pub fn scotch_like(g: &Graph, rng: &mut Rng) -> Permutation {
    scotch_like_in(g, rng, &mut Workspace::new())
}

/// [`scotch_like`] on a reusable workspace.
pub fn scotch_like_in(g: &Graph, rng: &mut Rng, ws: &mut Workspace) -> Permutation {
    dissection_with(g, rng, SCOTCH_SWITCH, ws, &|sub, ws| {
        min_degree_in(sub, Variant::Approximate, &mut ws.mindeg)
    })
}

/// PORD-style hybrid: ND on top (coarser), min-fill below `PORD_SWITCH`.
pub fn pord_like(g: &Graph, rng: &mut Rng) -> Permutation {
    pord_like_in(g, rng, &mut Workspace::new())
}

/// [`pord_like`] on a reusable workspace.
pub fn pord_like_in(g: &Graph, rng: &mut Rng, ws: &mut Workspace) -> Permutation {
    dissection_with(g, rng, PORD_SWITCH, ws, &|sub, ws| {
        min_degree_in(sub, Variant::MinFill, &mut ws.mindeg)
    })
}

/// SCOTCH-like hybrid as a plan-phase [`Reorderer`].
pub struct ScotchLike;

impl Reorderer for ScotchLike {
    fn algorithm(&self) -> ReorderAlgorithm {
        ReorderAlgorithm::Scotch
    }

    fn order(&self, g: &Graph, ws: &mut Workspace, seed: u64) -> Permutation {
        let mut rng = seed_rng(seed);
        scotch_like_in(g, &mut rng, ws)
    }
}

/// PORD-like hybrid as a plan-phase [`Reorderer`].
pub struct PordLike;

impl Reorderer for PordLike {
    fn algorithm(&self) -> ReorderAlgorithm {
        ReorderAlgorithm::Pord
    }

    fn order(&self, g: &Graph, ws: &mut Workspace, seed: u64) -> Permutation {
        let mut rng = seed_rng(seed);
        pord_like_in(g, &mut rng, ws)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reorder::metrics;
    use crate::reorder::mindeg::min_degree;
    use crate::reorder::{Permutation, ReorderAlgorithm};
    use crate::sparse::CooMatrix;
    use crate::util::prop;

    fn grid_matrix(nx: usize, ny: usize) -> crate::sparse::CsrMatrix {
        let idx = |x: usize, y: usize| y * nx + x;
        let n = nx * ny;
        let mut coo = CooMatrix::new(n, n);
        for y in 0..ny {
            for x in 0..nx {
                let v = idx(x, y);
                coo.push(v, v, 4.0);
                if x + 1 < nx {
                    coo.push_sym(v, idx(x + 1, y), -1.0);
                }
                if y + 1 < ny {
                    coo.push_sym(v, idx(x, y + 1), -1.0);
                }
            }
        }
        coo.to_csr()
    }

    #[test]
    fn hybrids_yield_valid_permutations() {
        let a = grid_matrix(18, 18);
        let g = Graph::from_matrix(&a);
        let mut rng = Rng::new(1);
        assert_eq!(scotch_like(&g, &mut rng).len(), 324);
        assert_eq!(pord_like(&g, &mut rng).len(), 324);
    }

    #[test]
    fn scotch_reduces_fill_vs_natural() {
        let a = grid_matrix(22, 22);
        let g = Graph::from_matrix(&a);
        let mut rng = Rng::new(2);
        let s_fill = metrics::symbolic_fill(&a, &scotch_like(&g, &mut rng));
        let nat = metrics::symbolic_fill(&a, &Permutation::identity(484));
        assert!(s_fill < nat, "scotch {s_fill} >= natural {nat}");
    }

    #[test]
    fn hybrids_differ_from_pure_nd_and_amd() {
        let a = grid_matrix(17, 17);
        let s = ReorderAlgorithm::Scotch.compute(&a, 9);
        let p = ReorderAlgorithm::Pord.compute(&a, 9);
        let n = ReorderAlgorithm::Nd.compute(&a, 9);
        let amd = ReorderAlgorithm::Amd.compute(&a, 9);
        assert_ne!(s, n);
        assert_ne!(s, amd);
        assert_ne!(p, n);
        assert_ne!(p, s);
    }

    #[test]
    fn small_graph_degenerates_to_local_order() {
        // below the switch threshold the hybrid IS the local ordering
        let a = grid_matrix(5, 5);
        let g = Graph::from_matrix(&a);
        let mut rng = Rng::new(3);
        let s = scotch_like(&g, &mut rng);
        let amd = min_degree(&g, Variant::Approximate);
        assert_eq!(s, amd);
    }

    #[test]
    fn prop_hybrids_valid_on_random() {
        prop::check("hybrid-valid", 10, |rng_p| {
            let n = rng_p.range(5, 300);
            let edges = prop::random_connected_edges(rng_p, n, 0.01);
            let g = Graph::from_edges(n, &edges);
            let mut rng = Rng::new(rng_p.next_u64());
            assert_eq!(scotch_like(&g, &mut rng).len(), n);
            assert_eq!(pord_like(&g, &mut rng).len(), n);
        });
    }
}

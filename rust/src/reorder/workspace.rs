//! Reusable scratch memory for the reordering algorithms.
//!
//! Every ordering needs the same O(n) working set — BFS visit flags and
//! queues (RCM), the quotient-graph elimination state (the min-degree
//! family, plus the leaf orderings of ND and the hybrids), and the
//! global→local map of recursive dissection. A [`Workspace`] owns all of
//! it once; algorithms reset the buffers they use instead of allocating,
//! so a sweep of many orderings (or many matrices) touches the allocator
//! only when a buffer must grow. One workspace belongs to one worker
//! thread — `ReorderEngine::sweep` hands each pool worker its own.
//!
//! Reuse is observation-free by construction: every algorithm fully
//! re-initializes the prefix of each buffer it reads, so a reused
//! workspace yields bit-identical permutations to a fresh one (property
//! tested in `tests/prop_reorder_engine.rs`).

use std::collections::VecDeque;

use super::mindeg::MinDegScratch;
use crate::graph::traversal::BfsScratch;

/// Scratch buffers shared by all reordering algorithms. Create once per
/// worker thread with [`Workspace::new`]; any algorithm can run on it in
/// any sequence.
#[derive(Default)]
pub struct Workspace {
    /// RCM: per-vertex "already queued" flags.
    pub(crate) placed: Vec<bool>,
    /// RCM: not-yet-ordered mask for the pseudo-peripheral search.
    pub(crate) mask: Vec<bool>,
    /// RCM: the classic Cuthill–McKee FIFO.
    pub(crate) queue: VecDeque<usize>,
    /// RCM: per-vertex unvisited-neighbor buffer (sorted by degree).
    pub(crate) children: Vec<usize>,
    /// RCM: the visit order under construction.
    pub(crate) order: Vec<usize>,
    /// BFS / pseudo-peripheral visited bitmap.
    pub(crate) bfs: BfsScratch,
    /// Quotient-graph minimum-degree engine state (also the leaf orderer
    /// of ND/SCOTCH/PORD — reused across every leaf of a dissection).
    pub(crate) mindeg: MinDegScratch,
    /// Dissection: global→local vertex map for induced subgraphs.
    /// Invariant: all `usize::MAX` between uses (`Graph::subgraph_in`).
    pub(crate) nd_local: Vec<usize>,
}

impl Workspace {
    pub fn new() -> Self {
        Self::default()
    }
}

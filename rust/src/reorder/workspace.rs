//! Reusable scratch memory for the reordering algorithms.
//!
//! Every ordering needs the same O(n) working set — BFS visit flags,
//! queues, and flat level storage (RCM's pseudo-peripheral search), the
//! quotient-graph elimination state (the min-degree family, plus the
//! leaf orderings of ND and the hybrids), and the global→local map and
//! induced-edge buffer of recursive dissection. A [`Workspace`] owns all
//! of it once; algorithms reset the buffers they use instead of
//! allocating, so a sweep of many orderings (or many matrices) touches
//! the allocator only when a buffer must grow. One workspace belongs to one worker
//! thread — `ReorderEngine::sweep` hands each pool worker its own.
//!
//! Reuse is observation-free by construction: every algorithm fully
//! re-initializes the prefix of each buffer it reads, so a reused
//! workspace yields bit-identical permutations to a fresh one (property
//! tested in `tests/prop_reorder_engine.rs`).
//!
//! Two reuse disciplines share the same buffers:
//!
//! * **per-worker** — `ReorderEngine::sweep` hands each pool worker its
//!   own warm workspace for the duration of a sweep (offline shape);
//! * **checkout/return** ([`WorkspacePool`]) — serving threads check a
//!   workspace out per request and the RAII [`PooledWorkspace`] guard
//!   parks it back on drop, so steady-state requests do zero BFS/mindeg
//!   scratch allocation even though requests hop across threads.

use std::collections::VecDeque;
use std::ops::{Deref, DerefMut};

use super::mindeg::MinDegScratch;
use crate::graph::traversal::{BfsScratch, LevelStructure};
use crate::util::pool::{ObjectPool, PoolStats};

/// Scratch buffers shared by all reordering algorithms. Create once per
/// worker thread with [`Workspace::new`]; any algorithm can run on it in
/// any sequence.
#[derive(Default)]
pub struct Workspace {
    /// RCM: per-vertex "already queued" flags.
    pub(crate) placed: Vec<bool>,
    /// RCM: not-yet-ordered mask for the pseudo-peripheral search.
    pub(crate) mask: Vec<bool>,
    /// RCM: the classic Cuthill–McKee FIFO.
    pub(crate) queue: VecDeque<usize>,
    /// RCM: per-vertex unvisited-neighbor buffer (sorted by degree).
    pub(crate) children: Vec<usize>,
    /// RCM: the visit order under construction.
    pub(crate) order: Vec<usize>,
    /// BFS / pseudo-peripheral visited bitmap (plus the candidate-BFS
    /// spare level structure).
    pub(crate) bfs: BfsScratch,
    /// RCM: workspace-owned level storage — every pseudo-peripheral BFS
    /// writes its flat level structure here instead of allocating.
    pub(crate) levels: LevelStructure,
    /// Quotient-graph minimum-degree engine state (also the leaf orderer
    /// of ND/SCOTCH/PORD — reused across every leaf of a dissection).
    pub(crate) mindeg: MinDegScratch,
    /// Dissection: global→local vertex map for induced subgraphs.
    /// Invariant: all `usize::MAX` between uses (`Graph::subgraph_in`).
    pub(crate) nd_local: Vec<usize>,
    /// Dissection: reusable induced-subgraph edge buffer — one buffer
    /// serves every level of the recursion (`Graph::subgraph_in_with`).
    pub(crate) nd_edges: Vec<(usize, usize)>,
}

impl Workspace {
    pub fn new() -> Self {
        Self::default()
    }
}

/// A shared free list of [`Workspace`]s for the serving path.
///
/// Checkout discipline: [`WorkspacePool::checkout`] returns a
/// [`PooledWorkspace`] RAII guard that derefs to `&mut Workspace` and
/// parks the workspace back into the pool when dropped — including on
/// panic unwind, so a failed request never leaks its scratch. The idle
/// list is bounded (`max_idle`), so a burst can temporarily construct
/// extra workspaces but the pool's steady-state footprint stays fixed.
///
/// No reset is performed on return: workspace reuse is observation-free
/// (see the module docs), so a parked workspace is indistinguishable
/// from a fresh one to every algorithm — only warmer.
pub struct WorkspacePool {
    inner: ObjectPool<Workspace>,
}

impl WorkspacePool {
    /// Pool keeping at most `max_idle` warm workspaces parked.
    pub fn new(max_idle: usize) -> Self {
        WorkspacePool {
            inner: ObjectPool::new(max_idle),
        }
    }

    /// Check a workspace out (warm if one is parked, fresh otherwise).
    pub fn checkout(&self) -> PooledWorkspace<'_> {
        PooledWorkspace {
            pool: self,
            ws: Some(self.inner.checkout_with(Workspace::new)),
        }
    }

    /// Checkout / create / reuse counters.
    pub fn stats(&self) -> PoolStats {
        self.inner.stats()
    }
}

impl Default for WorkspacePool {
    /// Room for one warm workspace per hardware thread.
    fn default() -> Self {
        Self::new(crate::util::pool::default_workers() + 1)
    }
}

/// RAII checkout from a [`WorkspacePool`]; derefs to [`Workspace`] and
/// returns it to the pool on drop.
pub struct PooledWorkspace<'a> {
    pool: &'a WorkspacePool,
    ws: Option<Workspace>,
}

impl Deref for PooledWorkspace<'_> {
    type Target = Workspace;

    fn deref(&self) -> &Workspace {
        self.ws.as_ref().expect("workspace present until drop")
    }
}

impl DerefMut for PooledWorkspace<'_> {
    fn deref_mut(&mut self) -> &mut Workspace {
        self.ws.as_mut().expect("workspace present until drop")
    }
}

impl Drop for PooledWorkspace<'_> {
    fn drop(&mut self) {
        if let Some(ws) = self.ws.take() {
            self.pool.inner.give_back(ws);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_returns_on_drop_and_reuses() {
        let pool = WorkspacePool::new(2);
        {
            let mut ws = pool.checkout();
            ws.order.push(7); // dirty it: reuse must be observation-free anyway
        }
        assert_eq!(pool.stats().idle, 1);
        {
            let _a = pool.checkout();
            let _b = pool.checkout(); // concurrent checkouts get distinct workspaces
            assert_eq!(pool.stats().idle, 0);
        }
        let s = pool.stats();
        assert_eq!(s.checkouts, 3);
        assert_eq!(s.creates, 2);
        assert_eq!(s.reuses, 1);
        assert_eq!(s.idle, 2);
    }

    #[test]
    fn guard_returns_workspace_on_panic() {
        let pool = WorkspacePool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ws = pool.checkout();
            panic!("request failed");
        }));
        assert!(r.is_err());
        assert_eq!(pool.stats().idle, 1, "workspace leaked on unwind");
    }
}


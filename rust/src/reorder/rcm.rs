//! Cuthill–McKee and Reverse Cuthill–McKee bandwidth-reducing orderings.
//!
//! CM (Cuthill & McKee 1969): BFS from a pseudo-peripheral vertex,
//! visiting each level's vertices in ascending-degree order. RCM (Liu &
//! Sherman 1976) reverses the CM order, which provably never increases —
//! and usually reduces — the envelope/profile. Handles disconnected
//! graphs by restarting from a fresh pseudo-peripheral vertex per
//! component (what SciPy's `reverse_cuthill_mckee` does).

use super::engine::Reorderer;
use super::workspace::Workspace;
use super::{Permutation, ReorderAlgorithm};
use crate::graph::traversal::pseudo_peripheral_into;
use crate::graph::Graph;

/// Cuthill–McKee visit order over all components, written into
/// `ws.order` (scratch buffers reused, no per-call allocation).
fn cm_order_in(g: &Graph, ws: &mut Workspace) {
    let n = g.n_vertices();
    ws.order.clear();
    ws.order.reserve(n);
    ws.placed.clear();
    ws.placed.resize(n, false);
    ws.mask.clear();
    ws.mask.resize(n, true); // not-yet-ordered vertices
    ws.queue.clear();

    // Components are processed in order of their lowest-index vertex;
    // within a component, BFS from a pseudo-peripheral start.
    for seed in 0..n {
        if ws.placed[seed] {
            continue;
        }
        // level storage is workspace-owned: the search allocates nothing
        let start = pseudo_peripheral_into(g, seed, &ws.mask, &mut ws.bfs, &mut ws.levels);
        // classic CM queue: visit in FIFO order, appending each vertex's
        // unvisited neighbors in ascending-degree order
        ws.queue.push_back(start);
        ws.placed[start] = true;
        while let Some(v) = ws.queue.pop_front() {
            ws.order.push(v);
            ws.mask[v] = false;
            ws.children.clear();
            for &u in g.neighbors(v) {
                if !ws.placed[u] {
                    ws.placed[u] = true;
                    ws.children.push(u);
                }
            }
            ws.children.sort_by_key(|&u| (g.degree(u), u));
            for &u in &ws.children {
                ws.queue.push_back(u);
            }
        }
    }
}

/// Cuthill–McKee ordering.
pub fn cuthill_mckee(g: &Graph) -> Permutation {
    cuthill_mckee_in(g, &mut Workspace::new())
}

/// [`cuthill_mckee`] on a reusable workspace.
pub fn cuthill_mckee_in(g: &Graph, ws: &mut Workspace) -> Permutation {
    cm_order_in(g, ws);
    Permutation::from_order(&ws.order)
}

/// Reverse Cuthill–McKee ordering.
pub fn reverse_cuthill_mckee(g: &Graph) -> Permutation {
    reverse_cuthill_mckee_in(g, &mut Workspace::new())
}

/// [`reverse_cuthill_mckee`] on a reusable workspace.
pub fn reverse_cuthill_mckee_in(g: &Graph, ws: &mut Workspace) -> Permutation {
    cm_order_in(g, ws);
    ws.order.reverse();
    Permutation::from_order(&ws.order)
}

/// Cuthill–McKee as a plan-phase [`Reorderer`].
pub struct Cm;

impl Reorderer for Cm {
    fn algorithm(&self) -> ReorderAlgorithm {
        ReorderAlgorithm::Cm
    }

    fn order(&self, g: &Graph, ws: &mut Workspace, _seed: u64) -> Permutation {
        cuthill_mckee_in(g, ws)
    }
}

/// Reverse Cuthill–McKee as a plan-phase [`Reorderer`].
pub struct Rcm;

impl Reorderer for Rcm {
    fn algorithm(&self) -> ReorderAlgorithm {
        ReorderAlgorithm::Rcm
    }

    fn order(&self, g: &Graph, ws: &mut Workspace, _seed: u64) -> Permutation {
        reverse_cuthill_mckee_in(g, ws)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::pattern::{bandwidth, profile};
    use crate::sparse::CooMatrix;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Random-permuted banded matrix: RCM should recover a small bandwidth.
    fn scrambled_band(n: usize, band: usize, seed: u64) -> crate::sparse::CsrMatrix {
        let mut rng = Rng::new(seed);
        let mut scramble: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut scramble);
        let mut coo = CooMatrix::new(n, n);
        for i in 0..n {
            coo.push(scramble[i], scramble[i], 4.0);
            for d in 1..=band {
                if i + d < n {
                    coo.push_sym(scramble[i], scramble[i + d], -1.0);
                }
            }
        }
        coo.to_csr()
    }

    #[test]
    fn rcm_recovers_band_structure() {
        let a = scrambled_band(200, 2, 11);
        let before = bandwidth(&a);
        let p = reverse_cuthill_mckee(&Graph::from_matrix(&a));
        let after = bandwidth(&p.apply(&a));
        assert!(after <= 4, "bandwidth {before} -> {after}");
        assert!(after < before);
    }

    #[test]
    fn rcm_profile_not_worse_than_cm() {
        let a = scrambled_band(150, 3, 13);
        let g = Graph::from_matrix(&a);
        let cm = cuthill_mckee(&g);
        let rcm = reverse_cuthill_mckee(&g);
        let p_cm = profile(&cm.apply(&a));
        let p_rcm = profile(&rcm.apply(&a));
        assert!(p_rcm <= p_cm, "rcm {p_rcm} > cm {p_cm}");
    }

    #[test]
    fn rcm_is_reverse_of_cm() {
        let a = scrambled_band(60, 2, 17);
        let g = Graph::from_matrix(&a);
        let cm = cuthill_mckee(&g);
        let rcm = reverse_cuthill_mckee(&g);
        assert_eq!(cm.reversed(), rcm);
    }

    #[test]
    fn handles_disconnected_graphs() {
        let g = Graph::from_edges(7, &[(0, 1), (1, 2), (4, 5), (5, 6)]);
        let p = reverse_cuthill_mckee(&g);
        assert_eq!(p.len(), 7); // validated bijection by construction
    }

    #[test]
    fn handles_isolated_vertices_and_empty() {
        let g = Graph::from_edges(3, &[]);
        let p = reverse_cuthill_mckee(&g);
        assert_eq!(p.len(), 3);
        let g0 = Graph::from_edges(0, &[]);
        assert_eq!(reverse_cuthill_mckee(&g0).len(), 0);
    }

    #[test]
    fn reused_workspace_is_bit_identical() {
        let mut ws = Workspace::new();
        for (n, band, seed) in [(120usize, 2usize, 3u64), (60, 4, 5), (200, 1, 9)] {
            let a = scrambled_band(n, band, seed);
            let g = Graph::from_matrix(&a);
            assert_eq!(reverse_cuthill_mckee_in(&g, &mut ws), reverse_cuthill_mckee(&g));
            assert_eq!(cuthill_mckee_in(&g, &mut ws), cuthill_mckee(&g));
        }
    }

    #[test]
    fn prop_rcm_valid_on_random_graphs() {
        prop::check("rcm-valid", 30, |rng| {
            let n = rng.range(2, 120);
            let edges = prop::random_sym_edges(rng, n, 0.1);
            let g = Graph::from_edges(n, &edges);
            let p = reverse_cuthill_mckee(&g);
            assert_eq!(p.len(), n);
        });
    }

    #[test]
    fn prop_rcm_never_wildly_worse_on_connected(){
        // On connected graphs RCM bandwidth should be <= n-1 trivially and
        // beat a random scramble on banded inputs (checked above); here we
        // assert it is deterministic and stable.
        prop::check("rcm-deterministic", 10, |rng| {
            let n = rng.range(5, 80);
            let edges = prop::random_connected_edges(rng, n, 0.05);
            let g = Graph::from_edges(n, &edges);
            assert_eq!(reverse_cuthill_mckee(&g), reverse_cuthill_mckee(&g));
        });
    }
}

//! Ordering-quality metrics: bandwidth, profile, symbolic fill and flops.
//!
//! These quantify what each algorithm family optimizes (paper Table 2):
//! RCM targets bandwidth/profile, the minimum-degree family and ND/hybrids
//! target fill/flops. The experiments use them both for analysis output
//! and for ablation benches.

use super::Permutation;
use crate::solver::etree::{col_counts, etree, symbolic_cost, SymbolicCost};
use crate::sparse::pattern;
use crate::sparse::CsrMatrix;

/// Bandwidth of `P A Pᵀ`.
pub fn bandwidth_under(a: &CsrMatrix, perm: &Permutation) -> usize {
    pattern::bandwidth(&perm.apply(a))
}

/// Profile (envelope) of `P A Pᵀ`.
pub fn profile_under(a: &CsrMatrix, perm: &Permutation) -> u64 {
    pattern::profile(&perm.apply(a))
}

/// Full symbolic cost of factorizing `P A Pᵀ` (pattern of A + Aᵀ).
pub fn symbolic_cost_under(a: &CsrMatrix, perm: &Permutation) -> SymbolicCost {
    let pa = perm.apply(a);
    let (indptr, indices) = pattern::symmetrized_pattern(&pa);
    let parent = etree(&indptr, &indices);
    let counts = col_counts(&indptr, &indices, &parent);
    symbolic_cost(&counts)
}

/// nnz(L) (including diagonal) of the factor of `P A Pᵀ`.
pub fn symbolic_fill(a: &CsrMatrix, perm: &Permutation) -> u64 {
    symbolic_cost_under(a, perm).fill
}

/// Multiply-add count of factorizing `P A Pᵀ`.
pub fn symbolic_flops(a: &CsrMatrix, perm: &Permutation) -> f64 {
    symbolic_cost_under(a, perm).flops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::reorder::rcm::reverse_cuthill_mckee;
    use crate::sparse::CooMatrix;
    use crate::util::rng::Rng;

    fn scrambled_band(n: usize, band: usize, seed: u64) -> CsrMatrix {
        let mut rng = Rng::new(seed);
        let mut s: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut s);
        let mut coo = CooMatrix::new(n, n);
        for i in 0..n {
            coo.push(s[i], s[i], 4.0);
            for d in 1..=band {
                if i + d < n {
                    coo.push_sym(s[i], s[i + d], -1.0);
                }
            }
        }
        coo.to_csr()
    }

    #[test]
    fn rcm_improves_bandwidth_metric() {
        let a = scrambled_band(120, 2, 3);
        let id = Permutation::identity(120);
        let rcm = reverse_cuthill_mckee(&Graph::from_matrix(&a));
        assert!(bandwidth_under(&a, &rcm) < bandwidth_under(&a, &id));
        assert!(profile_under(&a, &rcm) < profile_under(&a, &id));
    }

    #[test]
    fn symbolic_fill_at_least_n() {
        let a = scrambled_band(40, 1, 5);
        let fill = symbolic_fill(&a, &Permutation::identity(40));
        assert!(fill >= 40);
    }

    #[test]
    fn fill_invariant_under_relabeling_of_band() {
        // un-scrambling a banded matrix with its inverse scramble gives the
        // tridiagonal fill exactly: n + (n-1)*band
        let n = 60;
        let a = scrambled_band(n, 1, 7);
        let rcm = reverse_cuthill_mckee(&Graph::from_matrix(&a));
        let fill = symbolic_fill(&a, &rcm);
        // tridiagonal fill = n + (n-1); allow small slack for BFS ties
        assert!(fill <= (n + (n - 1) + 6) as u64, "fill {fill}");
    }

    #[test]
    fn flops_grow_with_fill() {
        let a = scrambled_band(80, 3, 9);
        let id = Permutation::identity(80);
        let rcm = reverse_cuthill_mckee(&Graph::from_matrix(&a));
        let (f_id, f_rcm) = (symbolic_flops(&a, &id), symbolic_flops(&a, &rcm));
        let (n_id, n_rcm) = (symbolic_fill(&a, &id), symbolic_fill(&a, &rcm));
        assert_eq!(f_id > f_rcm, n_id > n_rcm);
    }
}

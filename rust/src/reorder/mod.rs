//! Sparse matrix reordering — the seven orderings the paper evaluates
//! (Table 2) plus the natural (identity) baseline, structured as an
//! **analysis / plan / execute** flow:
//!
//! 1. **Analyze** ([`engine::MatrixAnalysis`]): symmetrize the matrix
//!    pattern into the adjacency [`crate::graph::Graph`] *once* per
//!    matrix, capture its degrees (shared with
//!    `features::extract_with_degrees`), and lazily label connected
//!    components. Every candidate ordering — and the classifier's
//!    feature pass — consumes this one analysis.
//! 2. **Plan** ([`engine::Reorderer`]): each algorithm is a stateless
//!    strategy whose O(n) scratch (BFS queues, degree buckets, quotient
//!    graph, partition maps) lives in a reusable
//!    [`workspace::Workspace`], so repeated orderings don't touch the
//!    allocator.
//! 3. **Execute** ([`engine::ReorderEngine`]): sweep many candidates
//!    concurrently over `util::pool` with one warm workspace per worker
//!    — the offline label-generation path the paper's selector
//!    amortizes — or run a single predicted ordering on the serving
//!    path.
//!
//! | Category (paper Table 2)      | Algorithms  | Module      |
//! |-------------------------------|-------------|-------------|
//! | bandwidth reduction           | RCM (+CM)   | [`rcm`]     |
//! | fill-in reduction             | MD, AMD, AMF, QAMD | [`mindeg`] |
//! | graph-based                   | ND          | [`nd`]      |
//! | hybrid fill-in + graph        | SCOTCH, PORD | [`hybrid`] |
//!
//! The legacy entry points ([`ReorderAlgorithm::compute`] /
//! [`ReorderAlgorithm::compute_on_graph`]) remain and are bit-identical
//! to the engine path — same symmetrization, same per-algorithm seeding
//! (`seed ^ 0x5ee_d`), same tie-breaking — they simply run the same
//! [`engine::Reorderer`]s on a fresh workspace. Quality metrics
//! (bandwidth, profile, symbolic fill/flops) live in [`metrics`].
//!
//! ## Serving-path reuse (cache + workspace pool)
//!
//! Production serving re-solves the same structural pattern under
//! different numerics (factorization-in-loop, time stepping), so the
//! hot path is built around two reuse layers:
//!
//! * **Ordering cache** ([`cache::OrderingCache`]) — a bounded, sharded
//!   map from `(PatternKey, algorithm, seed)` to `Arc<Permutation>`.
//!   *Keying*: the pattern fingerprint is taken from the symmetrized
//!   adjacency ([`engine::MatrixAnalysis::pattern_key`]), the canonical
//!   input every ordering is a pure function of; algorithm and seed
//!   complete the key, so a hit is bit-identical to a fresh compute by
//!   construction (property tested in `tests/prop_ordering_cache.rs`).
//!   *Invalidation*: none is ever needed — entries are immutable facts
//!   about a pattern; capacity pressure is handled by LRU-ish eviction
//!   (global recency ticks, stalest entry of the full shard evicted).
//!   Attach one to an engine with [`engine::ReorderEngine::with_cache`].
//! * **Workspace pool** ([`workspace::WorkspacePool`]) — serving threads
//!   check O(n) scratch out per request; the RAII guard returns it on
//!   drop (panic included). Checkout discipline: hold the checkout only
//!   for the ordering call, never across a solve, so a small pool serves
//!   many concurrent requests with zero steady-state allocation.

pub mod cache;
pub mod engine;
pub mod hybrid;
pub mod metrics;
pub mod mindeg;
pub mod nd;
pub mod rcm;
pub mod workspace;

pub use cache::{CacheConfig, CacheStats, OrderingCache, OrderingKey};
pub use engine::{reorderer, MatrixAnalysis, Reorderer, ReorderEngine};
pub use workspace::{PooledWorkspace, Workspace, WorkspacePool};

use crate::graph::Graph;
use crate::sparse::CsrMatrix;
use crate::util::rng::Rng;

/// Per-run RNG derivation shared by the legacy and engine paths (only
/// ND/SCOTCH/PORD draw from it, in their bisection).
pub(crate) fn seed_rng(seed: u64) -> Rng {
    Rng::new(seed ^ 0x5ee_d)
}

/// A permutation of `0..n`. `perm[old] = new`: old index `i` moves to
/// position `perm[i]` (scatter form, matching `CsrMatrix::permute_sym`).
#[derive(Clone, Debug, PartialEq)]
pub struct Permutation {
    perm: Vec<usize>,
}

impl Permutation {
    /// Build from scatter form, validating it is a bijection on `0..n`.
    pub fn new(perm: Vec<usize>) -> Self {
        let n = perm.len();
        let mut seen = vec![false; n];
        for &p in &perm {
            assert!(p < n, "permutation value {p} out of range");
            assert!(!seen[p], "duplicate permutation value {p}");
            seen[p] = true;
        }
        Permutation { perm }
    }

    /// Build from an elimination/visit *order*: `order[k]` is the old
    /// index placed at new position `k` (gather form).
    pub fn from_order(order: &[usize]) -> Self {
        let n = order.len();
        let mut perm = vec![usize::MAX; n];
        for (new, &old) in order.iter().enumerate() {
            assert!(old < n, "order value {old} out of range");
            assert_eq!(perm[old], usize::MAX, "duplicate order value {old}");
            perm[old] = new;
        }
        Permutation { perm }
    }

    pub fn identity(n: usize) -> Self {
        Permutation {
            perm: (0..n).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.perm.len()
    }

    pub fn is_empty(&self) -> bool {
        self.perm.is_empty()
    }

    /// Scatter form (`old -> new`).
    pub fn as_slice(&self) -> &[usize] {
        &self.perm
    }

    /// Gather form (`order[k]` = old index at new position k).
    pub fn order(&self) -> Vec<usize> {
        let mut order = vec![0usize; self.perm.len()];
        for (old, &new) in self.perm.iter().enumerate() {
            order[new] = old;
        }
        order
    }

    pub fn inverse(&self) -> Permutation {
        Permutation { perm: self.order() }
    }

    /// Reverse the ordering (CM -> RCM).
    pub fn reversed(&self) -> Permutation {
        let n = self.perm.len();
        Permutation {
            perm: self.perm.iter().map(|&p| n - 1 - p).collect(),
        }
    }

    /// Apply to a square matrix: `B = P A Pᵀ`.
    pub fn apply(&self, a: &CsrMatrix) -> CsrMatrix {
        a.permute_sym(&self.perm)
    }
}

/// The reordering algorithms under study. `Natural` is the no-op
/// baseline; the other seven are the paper's Table 2 set.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ReorderAlgorithm {
    Natural,
    Cm,
    Rcm,
    Md,
    Amd,
    Amf,
    Qamd,
    Nd,
    Scotch,
    Pord,
}

impl ReorderAlgorithm {
    /// The seven algorithms the paper benchmarks (Table 2).
    pub const PAPER_SET: [ReorderAlgorithm; 7] = [
        ReorderAlgorithm::Rcm,
        ReorderAlgorithm::Amd,
        ReorderAlgorithm::Amf,
        ReorderAlgorithm::Qamd,
        ReorderAlgorithm::Nd,
        ReorderAlgorithm::Scotch,
        ReorderAlgorithm::Pord,
    ];

    /// The four category representatives used as prediction labels
    /// (paper §3.2: RCM, AMD, ND, SCOTCH).
    pub const LABEL_SET: [ReorderAlgorithm; 4] = [
        ReorderAlgorithm::Amd,
        ReorderAlgorithm::Scotch,
        ReorderAlgorithm::Nd,
        ReorderAlgorithm::Rcm,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ReorderAlgorithm::Natural => "NATURAL",
            ReorderAlgorithm::Cm => "CM",
            ReorderAlgorithm::Rcm => "RCM",
            ReorderAlgorithm::Md => "MD",
            ReorderAlgorithm::Amd => "AMD",
            ReorderAlgorithm::Amf => "AMF",
            ReorderAlgorithm::Qamd => "QAMD",
            ReorderAlgorithm::Nd => "ND",
            ReorderAlgorithm::Scotch => "SCOTCH",
            ReorderAlgorithm::Pord => "PORD",
        }
    }

    pub fn from_name(name: &str) -> Option<ReorderAlgorithm> {
        let up = name.to_ascii_uppercase();
        Some(match up.as_str() {
            "NATURAL" => ReorderAlgorithm::Natural,
            "CM" => ReorderAlgorithm::Cm,
            "RCM" => ReorderAlgorithm::Rcm,
            "MD" => ReorderAlgorithm::Md,
            "AMD" => ReorderAlgorithm::Amd,
            "AMF" => ReorderAlgorithm::Amf,
            "QAMD" => ReorderAlgorithm::Qamd,
            "ND" => ReorderAlgorithm::Nd,
            "SCOTCH" => ReorderAlgorithm::Scotch,
            "PORD" => ReorderAlgorithm::Pord,
            _ => return None,
        })
    }

    /// Label index in [`Self::LABEL_SET`] (classifier class id), if this
    /// algorithm is one of the four representatives.
    pub fn label_index(&self) -> Option<usize> {
        Self::LABEL_SET.iter().position(|a| a == self)
    }

    /// Map a classifier class id back to its algorithm. Clamped against
    /// the actual label-set size — an out-of-range id is a bug upstream
    /// (debug-asserted); in release it degrades to the last class
    /// instead of silently remapping everything past 3 to RCM.
    pub fn from_label(label: usize) -> ReorderAlgorithm {
        let n_labels = Self::LABEL_SET.len();
        debug_assert!(label < n_labels, "classifier label {label} out of range");
        Self::LABEL_SET[label.min(n_labels - 1)]
    }

    /// Compute the ordering for a matrix. Deterministic given `seed`
    /// (only ND/SCOTCH/PORD use randomness, in their bisection).
    pub fn compute(&self, a: &CsrMatrix, seed: u64) -> Permutation {
        let g = Graph::from_matrix(a);
        self.compute_on_graph(&g, seed)
    }

    /// Compute the ordering on a prebuilt adjacency graph (fresh
    /// workspace; see [`Self::compute_with`] for the reusing form).
    pub fn compute_on_graph(&self, g: &Graph, seed: u64) -> Permutation {
        self.compute_with(g, seed, &mut Workspace::new())
    }

    /// Compute the ordering on a prebuilt graph with caller-owned
    /// scratch — the execute-phase primitive [`ReorderEngine`] uses.
    pub fn compute_with(&self, g: &Graph, seed: u64, ws: &mut Workspace) -> Permutation {
        engine::reorderer(*self).order(g, ws, seed)
    }
}

impl std::fmt::Display for ReorderAlgorithm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooMatrix;

    #[test]
    fn permutation_roundtrip() {
        let p = Permutation::new(vec![2, 0, 1]);
        assert_eq!(p.order(), vec![1, 2, 0]);
        let inv = p.inverse();
        // p ∘ p⁻¹ = id
        let composed: Vec<usize> = (0..3).map(|i| p.as_slice()[inv.as_slice()[i]]).collect();
        assert_eq!(composed, vec![0, 1, 2]);
    }

    #[test]
    fn from_order_matches_new() {
        // order: position 0 gets old 1, position 1 gets old 2, position 2 gets old 0
        let p = Permutation::from_order(&[1, 2, 0]);
        assert_eq!(p.as_slice(), &[2, 0, 1]);
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn rejects_non_bijection() {
        Permutation::new(vec![0, 0, 1]);
    }

    #[test]
    fn reversed_flips_positions() {
        let p = Permutation::identity(4).reversed();
        assert_eq!(p.as_slice(), &[3, 2, 1, 0]);
    }

    #[test]
    fn names_roundtrip() {
        for a in ReorderAlgorithm::PAPER_SET {
            assert_eq!(ReorderAlgorithm::from_name(a.name()), Some(a));
        }
        assert_eq!(ReorderAlgorithm::from_name("amd"), Some(ReorderAlgorithm::Amd));
        assert_eq!(ReorderAlgorithm::from_name("bogus"), None);
    }

    #[test]
    fn from_label_roundtrips_and_clamps() {
        for (k, &alg) in ReorderAlgorithm::LABEL_SET.iter().enumerate() {
            assert_eq!(ReorderAlgorithm::from_label(k), alg);
        }
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "out of range")]
    fn from_label_asserts_out_of_range_in_debug() {
        ReorderAlgorithm::from_label(ReorderAlgorithm::LABEL_SET.len());
    }

    #[test]
    fn label_indices_cover_0_to_3() {
        let mut idx: Vec<usize> = ReorderAlgorithm::LABEL_SET
            .iter()
            .map(|a| a.label_index().unwrap())
            .collect();
        idx.sort_unstable();
        assert_eq!(idx, vec![0, 1, 2, 3]);
        assert_eq!(ReorderAlgorithm::Md.label_index(), None);
    }

    #[test]
    fn every_algorithm_yields_valid_permutation() {
        // 5x5 grid Laplacian-ish pattern
        let n = 25;
        let mut coo = CooMatrix::new(n, n);
        for i in 0..n {
            coo.push(i, i, 4.0);
            if i % 5 != 4 {
                coo.push_sym(i, i + 1, -1.0);
            }
            if i + 5 < n {
                coo.push_sym(i, i + 5, -1.0);
            }
        }
        let a = coo.to_csr();
        for alg in [
            ReorderAlgorithm::Natural,
            ReorderAlgorithm::Cm,
            ReorderAlgorithm::Rcm,
            ReorderAlgorithm::Md,
            ReorderAlgorithm::Amd,
            ReorderAlgorithm::Amf,
            ReorderAlgorithm::Qamd,
            ReorderAlgorithm::Nd,
            ReorderAlgorithm::Scotch,
            ReorderAlgorithm::Pord,
        ] {
            let p = alg.compute(&a, 42);
            assert_eq!(p.len(), n, "{alg}");
            // Permutation::new already validates bijection on construction
            let b = p.apply(&a);
            assert_eq!(b.nnz(), a.nnz(), "{alg}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut coo = CooMatrix::new(30, 30);
        for i in 0..30 {
            coo.push(i, i, 2.0);
            if i > 0 {
                coo.push_sym(i, i - 1, -1.0);
            }
            if i >= 6 {
                coo.push_sym(i, i - 6, -0.5);
            }
        }
        let a = coo.to_csr();
        for alg in ReorderAlgorithm::PAPER_SET {
            let p1 = alg.compute(&a, 7);
            let p2 = alg.compute(&a, 7);
            assert_eq!(p1, p2, "{alg} not deterministic");
        }
    }
}

//! Quotient-graph minimum-degree family: MD, AMD, AMF, QAMD.
//!
//! One elimination engine (Tinney/Walker elimination on a quotient graph
//! with elements, element absorption, and supervariable mass elimination)
//! parameterized by the pivot-scoring rule:
//!
//! * [`Variant::Exact`] — exact weighted external degree (classic MD,
//!   Tinney & Walker 1967).
//! * [`Variant::Approximate`] — the AMD-style upper bound
//!   `|A_v| + Σ_e |L_e \ v|` computed in O(|adj|) per update (Amestoy,
//!   Davis & Duff 1996).
//! * [`Variant::MinFill`] — approximate minimum fill: score is an upper
//!   bound on the new fill a pivot would create (`d(d-1)/2` minus the
//!   cliques already covered by its elements).
//! * [`Variant::QuasiDense`] — QAMD: the AMD score plus quasi-dense row
//!   postponement (rows whose degree exceeds a threshold are pushed to
//!   the end of the elimination, as in MUMPS' QAMD).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::engine::Reorderer;
use super::workspace::Workspace;
use super::{Permutation, ReorderAlgorithm};
use crate::graph::Graph;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    Exact,
    Approximate,
    MinFill,
    QuasiDense,
}

#[derive(Default)]
struct State {
    /// Variable-variable adjacency (original edges, pruned as elements form).
    adj: Vec<Vec<usize>>,
    /// Elements adjacent to each variable.
    elems: Vec<Vec<usize>>,
    /// Variables on each element's boundary (may contain dead vars until
    /// the next sweep).
    elem_vars: Vec<Vec<usize>>,
    elem_alive: Vec<bool>,
    /// Cached total weight of alive vars in each element.
    elem_weight: Vec<usize>,
    /// Variable status: alive = not eliminated and not merged.
    alive: Vec<bool>,
    /// Supervariable weight (number of original variables represented).
    weight: Vec<usize>,
    /// Flattened list of variables merged into this representative.
    followers: Vec<Vec<usize>>,
    score: Vec<i64>,
    marker: Vec<u32>,
    mark: u32,
}

impl State {
    /// Re-initialize for a fresh elimination of `g`, reusing every
    /// allocation a previous run left behind. A reset state is
    /// indistinguishable from a newly constructed one (same contents,
    /// capacities may differ), so reuse cannot change the ordering.
    fn reset(&mut self, g: &Graph) {
        let n = g.n_vertices();
        self.adj.resize_with(n, Vec::new);
        self.elems.resize_with(n, Vec::new);
        self.followers.resize_with(n, Vec::new);
        for v in 0..n {
            self.adj[v].clear();
            self.adj[v].extend_from_slice(g.neighbors(v));
            self.elems[v].clear();
            self.followers[v].clear();
        }
        self.elem_vars.clear();
        self.elem_alive.clear();
        self.elem_weight.clear();
        self.alive.clear();
        self.alive.resize(n, true);
        self.weight.clear();
        self.weight.resize(n, 1);
        self.score.clear();
        self.score.resize(n, 0);
        self.marker.clear();
        self.marker.resize(n, 0);
        self.mark = 0;
    }

    fn n(&self) -> usize {
        self.alive.len()
    }

    fn next_mark(&mut self) -> u32 {
        self.mark += 1;
        self.mark
    }

    /// Drop dead variables from an element's boundary, refresh its cached
    /// weight, and return the weight.
    fn compact_element(&mut self, e: usize) -> usize {
        // Take the list out to appease the borrow checker.
        let mut vars = std::mem::take(&mut self.elem_vars[e]);
        vars.retain(|&v| self.alive[v]);
        let w: usize = vars.iter().map(|&v| self.weight[v]).sum();
        self.elem_weight[e] = w;
        self.elem_vars[e] = vars;
        w
    }

    /// Union of `adj[v]` and all element boundaries of `v`, excluding `v`
    /// itself and dead vars. Marks the result with a fresh marker and
    /// returns (vars, total weight).
    fn neighborhood(&mut self, v: usize) -> (Vec<usize>, usize) {
        let m = self.next_mark();
        self.marker[v] = m;
        let mut out = Vec::new();
        let mut wsum = 0usize;
        let adj = std::mem::take(&mut self.adj[v]);
        for &u in &adj {
            if self.alive[u] && self.marker[u] != m {
                self.marker[u] = m;
                wsum += self.weight[u];
                out.push(u);
            }
        }
        self.adj[v] = adj;
        let elems = std::mem::take(&mut self.elems[v]);
        for &e in &elems {
            if !self.elem_alive[e] {
                continue;
            }
            let vars = std::mem::take(&mut self.elem_vars[e]);
            for &u in &vars {
                if self.alive[u] && self.marker[u] != m {
                    self.marker[u] = m;
                    wsum += self.weight[u];
                    out.push(u);
                }
            }
            self.elem_vars[e] = vars;
        }
        self.elems[v] = elems;
        (out, wsum)
    }

    /// AMD-style approximate weighted external degree.
    fn approx_degree(&mut self, v: usize) -> i64 {
        let mut d = 0i64;
        let adj = std::mem::take(&mut self.adj[v]);
        for &u in &adj {
            if self.alive[u] {
                d += self.weight[u] as i64;
            }
        }
        self.adj[v] = adj;
        let elems = std::mem::take(&mut self.elems[v]);
        for &e in &elems {
            if self.elem_alive[e] {
                let w = self.elem_weight[e] as i64 - self.weight[v] as i64;
                d += w.max(0);
            }
        }
        self.elems[v] = elems;
        d
    }

    /// Exact weighted external degree (set union).
    fn exact_degree(&mut self, v: usize) -> i64 {
        let (_, w) = self.neighborhood(v);
        w as i64
    }

    /// Approximate fill score for AMF.
    fn fill_score(&mut self, v: usize) -> i64 {
        let d = self.approx_degree(v);
        let mut covered = 0i64;
        let elems = std::mem::take(&mut self.elems[v]);
        for &e in &elems {
            if self.elem_alive[e] {
                let w = (self.elem_weight[e] as i64 - self.weight[v] as i64).max(0);
                covered += w * (w - 1) / 2;
            }
        }
        self.elems[v] = elems;
        (d * (d - 1) / 2 - covered).max(0)
    }

    fn rescore(&mut self, v: usize, variant: Variant, dense_threshold: i64) -> i64 {
        let s = match variant {
            Variant::Exact => self.exact_degree(v),
            Variant::Approximate => self.approx_degree(v),
            Variant::MinFill => self.fill_score(v),
            Variant::QuasiDense => {
                let d = self.approx_degree(v);
                if d > dense_threshold {
                    // postpone quasi-dense rows; keep relative order by degree
                    d + (self.n() as i64).pow(2)
                } else {
                    d
                }
            }
        };
        self.score[v] = s;
        s
    }
}

/// Reusable scratch for the quotient-graph elimination: the per-vertex
/// state, the pivot heap, and the output order buffer. One instance
/// serves any number of [`min_degree_in`] calls (it is the workhorse
/// behind every ND/hybrid leaf ordering in a dissection sweep).
#[derive(Default)]
pub struct MinDegScratch {
    st: State,
    order: Vec<usize>,
    heap: BinaryHeap<Reverse<(i64, usize)>>,
}

/// Compute a minimum-degree-family ordering.
pub fn min_degree(g: &Graph, variant: Variant) -> Permutation {
    min_degree_in(g, variant, &mut MinDegScratch::default())
}

/// [`min_degree`] on reusable scratch (no per-call allocation once the
/// scratch has warmed up to the largest graph seen).
pub fn min_degree_in(g: &Graph, variant: Variant, scratch: &mut MinDegScratch) -> Permutation {
    let n = g.n_vertices();
    if n == 0 {
        return Permutation::identity(0);
    }
    let MinDegScratch { st, order, heap } = scratch;
    st.reset(g);

    // QAMD dense-row threshold: 10·avg degree, at least 16 (MUMPS uses a
    // similar multiple-of-average heuristic).
    let avg_deg = (2 * g.n_edges()) as f64 / n as f64;
    let dense_threshold = ((10.0 * avg_deg) as i64).max(16);

    heap.clear();
    for v in 0..n {
        let s = st.rescore(v, variant, dense_threshold);
        heap.push(Reverse((s, v)));
    }

    order.clear();
    order.reserve(n);
    let mut eliminated = 0usize;

    while eliminated < n {
        // Pop the minimum-score alive variable with a current score.
        let p = loop {
            match heap.pop() {
                Some(Reverse((s, v))) => {
                    if st.alive[v] && st.score[v] == s {
                        break v;
                    }
                }
                None => {
                    // Safety net: heap staleness exhausted it; find any
                    // alive variable directly.
                    let v = (0..n).find(|&v| st.alive[v]).expect("vars remain");
                    break v;
                }
            }
        };

        // Lp = neighborhood of p (variables of the new element).
        let (lp, _) = st.neighborhood(p);

        // Eliminate p (and its merged followers).
        st.alive[p] = false;
        eliminated += st.weight[p];
        order.push(p);
        let fs = std::mem::take(&mut st.followers[p]);
        order.extend(fs);

        // Absorb p's elements into the new one.
        let old_elems = std::mem::take(&mut st.elems[p]);
        for &e in &old_elems {
            st.elem_alive[e] = false;
            st.elem_vars[e].clear();
        }
        if lp.is_empty() {
            continue;
        }
        let e_new = st.elem_vars.len();
        st.elem_vars.push(lp.clone());
        st.elem_alive.push(true);
        st.elem_weight.push(0);
        st.compact_element(e_new);

        // Update each boundary variable: prune adj of {p} ∪ Lp (covered by
        // the new element), drop absorbed elements, attach e_new.
        let m = st.next_mark();
        st.marker[p] = m;
        for &u in &lp {
            st.marker[u] = m;
        }
        for &v in &lp {
            let mark = st.mark;
            let marker = &st.marker;
            st.adj[v].retain(|&u| marker[u] != mark);
            let elem_alive = &st.elem_alive;
            st.elems[v].retain(|&e| elem_alive[e]);
            st.elems[v].push(e_new);
        }

        // Refresh cached weights of elements touching Lp (their boundaries
        // lost p and possibly merged vars).
        let mut touched: Vec<usize> = lp
            .iter()
            .flat_map(|&v| st.elems[v].iter().copied())
            .collect();
        touched.sort_unstable();
        touched.dedup();
        for e in touched {
            if st.elem_alive[e] {
                st.compact_element(e);
            }
        }

        // Supervariable detection (mass elimination): merge boundary vars
        // with identical quotient-graph adjacency.
        merge_indistinguishable(st, &lp);

        // Rescore and re-push boundary variables.
        for &v in &lp {
            if st.alive[v] {
                let s = st.rescore(v, variant, dense_threshold);
                heap.push(Reverse((s, v)));
            }
        }
    }

    Permutation::from_order(order)
}

/// The min-degree family as plan-phase [`Reorderer`]s: one unit value
/// per scoring rule (MD / AMD / AMF / QAMD).
pub struct MinDeg(pub Variant);

impl Reorderer for MinDeg {
    fn algorithm(&self) -> ReorderAlgorithm {
        match self.0 {
            Variant::Exact => ReorderAlgorithm::Md,
            Variant::Approximate => ReorderAlgorithm::Amd,
            Variant::MinFill => ReorderAlgorithm::Amf,
            Variant::QuasiDense => ReorderAlgorithm::Qamd,
        }
    }

    fn order(&self, g: &Graph, ws: &mut Workspace, _seed: u64) -> Permutation {
        min_degree_in(g, self.0, &mut ws.mindeg)
    }
}

/// Merge indistinguishable variables among `candidates`: same adj set and
/// same element set (after pruning). Classic AMD supervariable detection
/// via hashing + exact verification.
fn merge_indistinguishable(st: &mut State, candidates: &[usize]) {
    use std::collections::HashMap;
    let mut buckets: HashMap<u64, Vec<usize>> = HashMap::new();
    for &v in candidates {
        if !st.alive[v] {
            continue;
        }
        st.adj[v].sort_unstable();
        st.elems[v].sort_unstable();
        let mut h = 0xcbf29ce484222325u64; // FNV
        for &u in &st.adj[v] {
            h = (h ^ u as u64).wrapping_mul(0x100000001b3);
        }
        h = (h ^ 0xdeadbeef).wrapping_mul(0x100000001b3);
        for &e in &st.elems[v] {
            h = (h ^ e as u64).wrapping_mul(0x100000001b3);
        }
        buckets.entry(h).or_default().push(v);
    }
    for (_, group) in buckets {
        if group.len() < 2 {
            continue;
        }
        for i in 0..group.len() {
            let rep = group[i];
            if !st.alive[rep] {
                continue;
            }
            for j in (i + 1)..group.len() {
                let v = group[j];
                if !st.alive[v] {
                    continue;
                }
                if st.adj[rep] == st.adj[v] && st.elems[rep] == st.elems[v] {
                    // merge v into rep
                    st.alive[v] = false;
                    st.weight[rep] += st.weight[v];
                    let mut fv = std::mem::take(&mut st.followers[v]);
                    st.followers[rep].push(v);
                    st.followers[rep].append(&mut fv);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reorder::metrics;
    use crate::sparse::CooMatrix;
    use crate::util::prop;

    fn grid_graph(nx: usize, ny: usize) -> Graph {
        let idx = |x: usize, y: usize| y * nx + x;
        let mut edges = Vec::new();
        for y in 0..ny {
            for x in 0..nx {
                if x + 1 < nx {
                    edges.push((idx(x, y), idx(x + 1, y)));
                }
                if y + 1 < ny {
                    edges.push((idx(x, y), idx(x, y + 1)));
                }
            }
        }
        Graph::from_edges(nx * ny, &edges)
    }

    fn grid_matrix(nx: usize, ny: usize) -> crate::sparse::CsrMatrix {
        let g = grid_graph(nx, ny);
        let n = g.n_vertices();
        let mut coo = CooMatrix::new(n, n);
        for v in 0..n {
            coo.push(v, v, 4.0);
            for &u in g.neighbors(v) {
                if u > v {
                    coo.push_sym(v, u, -1.0);
                }
            }
        }
        coo.to_csr()
    }

    #[test]
    fn all_variants_yield_valid_permutations() {
        let g = grid_graph(8, 8);
        for variant in [
            Variant::Exact,
            Variant::Approximate,
            Variant::MinFill,
            Variant::QuasiDense,
        ] {
            let p = min_degree(&g, variant);
            assert_eq!(p.len(), 64, "{variant:?}");
        }
    }

    #[test]
    fn star_center_eliminated_last() {
        // Star: center has degree n-1, leaves degree 1. Any min-degree
        // variant must eliminate all leaves before the center.
        let n = 20;
        let edges: Vec<(usize, usize)> = (1..n).map(|i| (0, i)).collect();
        let g = Graph::from_edges(n, &edges);
        let p = min_degree(&g, Variant::Approximate);
        let pos_center = p.as_slice()[0];
        // after the first leaf eliminations the center may merge, but its
        // position must be in the last supernode
        assert!(pos_center >= 1, "center eliminated first");
    }

    #[test]
    fn md_beats_natural_fill_on_grid() {
        let a = grid_matrix(12, 12);
        let natural = metrics::symbolic_fill(&a, &Permutation::identity(144));
        for variant in [Variant::Exact, Variant::Approximate] {
            let p = min_degree(&Graph::from_matrix(&a), variant);
            let fill = metrics::symbolic_fill(&a, &p);
            assert!(
                fill < natural,
                "{variant:?}: fill {fill} >= natural {natural}"
            );
        }
    }

    #[test]
    fn amd_close_to_md_quality() {
        let a = grid_matrix(10, 10);
        let g = Graph::from_matrix(&a);
        let md = metrics::symbolic_fill(&a, &min_degree(&g, Variant::Exact));
        let amd = metrics::symbolic_fill(&a, &min_degree(&g, Variant::Approximate));
        // AMD is an approximation; allow 2x slack (paper: "similar quality")
        assert!(amd as f64 <= 2.0 * md as f64, "amd {amd} vs md {md}");
    }

    #[test]
    fn variants_differ_on_structured_input() {
        // The four scoring rules should not all produce the same ordering
        // on a non-trivial graph (otherwise the selection problem is moot).
        let a = grid_matrix(9, 9);
        let g = Graph::from_matrix(&a);
        let perms: Vec<Permutation> = [
            Variant::Exact,
            Variant::Approximate,
            Variant::MinFill,
            Variant::QuasiDense,
        ]
        .iter()
        .map(|&v| min_degree(&g, v))
        .collect();
        let distinct = perms
            .iter()
            .enumerate()
            .any(|(i, p)| perms.iter().skip(i + 1).any(|q| p != q));
        assert!(distinct);
    }

    #[test]
    fn qamd_postpones_dense_rows() {
        // Arrow matrix: one dense row/col (0), rest banded. QAMD must put
        // vertex 0 at (or near) the end.
        let n = 60;
        let mut edges: Vec<(usize, usize)> = (1..n).map(|i| (0, i)).collect();
        for i in 1..n - 1 {
            edges.push((i, i + 1));
        }
        let g = Graph::from_edges(n, &edges);
        let p = min_degree(&g, Variant::QuasiDense);
        let pos = p.as_slice()[0];
        assert!(pos >= n - 3, "dense row at position {pos}, expected near {n}");
    }

    #[test]
    fn handles_disconnected_and_isolated() {
        let g = Graph::from_edges(6, &[(0, 1), (2, 3)]);
        for variant in [Variant::Approximate, Variant::MinFill] {
            let p = min_degree(&g, variant);
            assert_eq!(p.len(), 6, "{variant:?}");
        }
    }

    #[test]
    fn empty_graph_ok() {
        let g = Graph::from_edges(0, &[]);
        assert_eq!(min_degree(&g, Variant::Approximate).len(), 0);
    }

    #[test]
    fn reused_scratch_is_bit_identical() {
        // one scratch across variants AND across graphs of different
        // sizes must replay the fresh-scratch orderings exactly
        let mut scratch = MinDegScratch::default();
        for (nx, ny) in [(9usize, 9usize), (5, 4), (12, 7)] {
            let g = grid_graph(nx, ny);
            for variant in [
                Variant::Exact,
                Variant::Approximate,
                Variant::MinFill,
                Variant::QuasiDense,
            ] {
                assert_eq!(
                    min_degree_in(&g, variant, &mut scratch),
                    min_degree(&g, variant),
                    "{variant:?} on {nx}x{ny}"
                );
            }
        }
    }

    #[test]
    fn prop_valid_on_random_graphs() {
        prop::check("mindeg-valid", 20, |rng| {
            let n = rng.range(2, 90);
            let edges = prop::random_sym_edges(rng, n, 0.08);
            let g = Graph::from_edges(n, &edges);
            for variant in [Variant::Approximate, Variant::MinFill, Variant::QuasiDense] {
                let p = min_degree(&g, variant);
                assert_eq!(p.len(), n);
            }
        });
    }

    #[test]
    fn prop_supervariable_merge_preserves_count() {
        // complete bipartite-ish graphs trigger heavy merging
        prop::check("mindeg-merge", 10, |rng| {
            let k = rng.range(2, 8);
            let m = rng.range(2, 8);
            let mut edges = Vec::new();
            for i in 0..k {
                for j in 0..m {
                    edges.push((i, k + j));
                }
            }
            let g = Graph::from_edges(k + m, &edges);
            let p = min_degree(&g, Variant::Approximate);
            assert_eq!(p.len(), k + m);
        });
    }
}

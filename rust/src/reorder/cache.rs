//! Pattern-keyed ordering cache — the serving path's repeat-request
//! fast lane.
//!
//! Reordering is a pure function of `(pattern, algorithm, seed)`: values
//! never enter an ordering, and every algorithm here is deterministic
//! given its seed. Workloads that re-solve the *same structural pattern*
//! under different numerics (factorization-in-loop, time stepping,
//! Newton iterations) therefore recompute byte-identical permutations on
//! every request. [`OrderingCache`] memoizes them.
//!
//! The sharded-LRU mechanics (bounded capacity, recency-tick eviction,
//! lock-free counters, compute-outside-the-lock misses) live in the
//! generic [`crate::util::cache::ShardedCache`], shared with the
//! solver's symbolic-plan cache ([`crate::solver::plan_cache`]); this
//! module owns only the *keying policy*:
//!
//! * [`OrderingKey`] is the [`PatternKey`] structural fingerprint of the
//!   **symmetrized adjacency** (not the raw matrix — see
//!   [`OrderingKey::for_analysis`]) plus the algorithm and the reorder
//!   seed. Including the seed keeps the ND/SCOTCH/PORD bisection
//!   randomness inside the key, so a hit is bit-identical to a fresh
//!   compute by construction (property tested in
//!   `tests/prop_ordering_cache.rs`).
//!
//! Values are `Arc<Permutation>` so a hit is one atomic increment — the
//! caller, the cache, and an in-flight solve can all hold the same
//! ordering without copying the O(n) vector.

use std::sync::Arc;

use super::engine::{reorderer, MatrixAnalysis};
use super::workspace::WorkspacePool;
use super::{Permutation, ReorderAlgorithm};
use crate::sparse::PatternKey;
use crate::util::cache::ShardedCache;

pub use crate::util::cache::{CacheConfig, CacheStats, Fetch};

/// Cache identity of one ordering: the structural fingerprint, which
/// algorithm ran, and the seed its randomness derived from.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct OrderingKey {
    pub pattern: PatternKey,
    pub algorithm: ReorderAlgorithm,
    pub seed: u64,
}

impl OrderingKey {
    /// The canonical key for an ordering of an analyzed matrix — every
    /// cache consumer builds keys through here, so the keying policy
    /// (fingerprint of the *symmetrized adjacency*, not the raw matrix)
    /// lives in one place.
    pub fn for_analysis(
        analysis: &MatrixAnalysis,
        algorithm: ReorderAlgorithm,
        seed: u64,
    ) -> OrderingKey {
        OrderingKey {
            pattern: analysis.pattern_key(),
            algorithm,
            seed,
        }
    }
}

/// Bounded, sharded `(PatternKey, algorithm, seed) → Arc<Permutation>`
/// map with LRU-ish eviction (a [`ShardedCache`] instantiation — see
/// `util::cache` for the mechanics, the module docs for the keying).
pub struct OrderingCache {
    inner: ShardedCache<OrderingKey, Permutation>,
}

impl OrderingCache {
    pub fn new(cfg: CacheConfig) -> Self {
        OrderingCache {
            inner: ShardedCache::new(cfg),
        }
    }

    pub fn with_default_config() -> Self {
        Self::new(CacheConfig::default())
    }

    /// Effective capacity (`shards * per_shard`, ≤ the configured one).
    pub fn capacity(&self) -> usize {
        self.inner.capacity()
    }

    /// Resident entries (sums shard sizes; momentary under concurrency).
    pub fn len(&self) -> usize {
        self.inner.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    /// Counted lookup: `Some` stamps recency and counts a hit, `None`
    /// counts a miss.
    pub fn get(&self, key: &OrderingKey) -> Option<Arc<Permutation>> {
        self.inner.get(key)
    }

    /// Insert (idempotent: an existing entry for `key` is kept — the
    /// value is a pure function of the key, so both are identical and
    /// keeping the resident one preserves its recency). Evicts the
    /// stalest entry of the target shard when it is full.
    pub fn insert(&self, key: OrderingKey, perm: Arc<Permutation>) -> Arc<Permutation> {
        self.inner.insert(key, perm)
    }

    /// The serving primitive: one counted lookup; on miss, compute
    /// *outside* the shard lock and insert — with in-flight dedup:
    /// concurrent misses for the same key elect one leader, every other
    /// caller parks and adopts the leader's `Arc` ([`Fetch::Coalesced`]),
    /// so a cold-path stampede costs one reordering, not k. Every caller
    /// observes one canonical permutation either way.
    pub fn get_or_compute(
        &self,
        key: OrderingKey,
        compute: impl FnOnce() -> Permutation,
    ) -> (Arc<Permutation>, Fetch) {
        self.inner.get_or_compute(key, compute)
    }

    /// The request-path composition of cache + pool, shared by the
    /// serving engine and the selection pipeline so the key construction
    /// and the checkout discipline live in exactly one place: one
    /// counted lookup keyed off the analysis fingerprint; on miss, the
    /// algorithm runs on a workspace checked out of `pool` — the
    /// checkout happens only on the miss path, so warm traffic never
    /// touches the pool.
    pub fn fetch_or_order(
        &self,
        analysis: &MatrixAnalysis,
        algorithm: ReorderAlgorithm,
        seed: u64,
        pool: &WorkspacePool,
    ) -> (Arc<Permutation>, Fetch) {
        let key = OrderingKey::for_analysis(analysis, algorithm, seed);
        self.get_or_compute(key, || {
            let mut ws = pool.checkout();
            reorderer(algorithm).order(analysis.graph(), &mut ws, seed)
        })
    }

    pub fn stats(&self) -> CacheStats {
        self.inner.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(pattern_hash: u64, n: usize, alg: ReorderAlgorithm, seed: u64) -> OrderingKey {
        OrderingKey {
            pattern: PatternKey {
                n,
                nnz: 3 * n,
                hash: pattern_hash,
            },
            algorithm: alg,
            seed,
        }
    }

    #[test]
    fn miss_then_hit_round_trip() {
        let cache = OrderingCache::with_default_config();
        let k = key(0xABCD, 5, ReorderAlgorithm::Amd, 7);
        let (p1, f1) = cache.get_or_compute(k, || Permutation::identity(5));
        assert_eq!(f1, Fetch::Led);
        let (p2, f2) = cache.get_or_compute(k, || panic!("must not recompute"));
        assert!(f2.is_hit());
        assert!(Arc::ptr_eq(&p1, &p2));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.inserts), (1, 1, 1));
        assert_eq!(s.lookups(), 2);
        assert_eq!(s.entries, 1);
    }

    #[test]
    fn distinct_algorithms_and_seeds_are_distinct_entries() {
        let cache = OrderingCache::with_default_config();
        let mut n_entries = 0;
        for alg in [ReorderAlgorithm::Amd, ReorderAlgorithm::Rcm] {
            for seed in [1u64, 2] {
                let (_, fetch) =
                    cache.get_or_compute(key(9, 4, alg, seed), || Permutation::identity(4));
                assert!(!fetch.is_hit());
                n_entries += 1;
            }
        }
        assert_eq!(cache.len(), n_entries);
    }

    #[test]
    fn capacity_is_never_exceeded_and_evictions_count() {
        let cache = OrderingCache::new(CacheConfig {
            capacity: 6,
            shards: 3,
        });
        assert!(cache.capacity() <= 6);
        for i in 0..50u64 {
            cache.insert(
                key(i, 4, ReorderAlgorithm::Amd, 0),
                Arc::new(Permutation::identity(4)),
            );
            assert!(cache.len() <= cache.capacity(), "overflow at insert {i}");
        }
        let s = cache.stats();
        assert!(s.evictions > 0);
        assert_eq!(s.inserts, 50);
        assert_eq!(s.entries, cache.len());
    }

    #[test]
    fn insert_is_idempotent() {
        let cache = OrderingCache::with_default_config();
        let k = key(7, 4, ReorderAlgorithm::Nd, 3);
        let first = cache.insert(k, Arc::new(Permutation::identity(4)));
        let second = cache.insert(k, Arc::new(Permutation::identity(4)));
        assert!(Arc::ptr_eq(&first, &second));
        assert_eq!(cache.stats().inserts, 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn degenerate_configs_are_clamped() {
        let cache = OrderingCache::new(CacheConfig {
            capacity: 0,
            shards: 0,
        });
        assert_eq!(cache.capacity(), 1);
        let tiny = OrderingCache::new(CacheConfig {
            capacity: 2,
            shards: 16,
        });
        assert!(tiny.capacity() <= 2);
    }
}

//! Pattern-keyed ordering cache — the serving path's repeat-request
//! fast lane.
//!
//! Reordering is a pure function of `(pattern, algorithm, seed)`: values
//! never enter an ordering, and every algorithm here is deterministic
//! given its seed. Workloads that re-solve the *same structural pattern*
//! under different numerics (factorization-in-loop, time stepping,
//! Newton iterations) therefore recompute byte-identical permutations on
//! every request. [`OrderingCache`] memoizes them:
//!
//! * **Keying** ([`OrderingKey`]): the [`PatternKey`] structural
//!   fingerprint (order + nnz + row-ptr/col-idx hash) plus the algorithm
//!   and the reorder seed. Including the seed keeps the ND/SCOTCH/PORD
//!   bisection randomness inside the key, so a hit is bit-identical to a
//!   fresh compute by construction (property tested in
//!   `tests/prop_ordering_cache.rs`).
//! * **Sharding**: entries are spread over `shards` independent
//!   mutex-protected maps selected by the key hash, so concurrent
//!   requests for different patterns rarely contend on one lock.
//! * **Eviction**: bounded, LRU-ish. Every hit stamps the entry with a
//!   global monotone tick; when a shard is full the stalest entry in
//!   that shard is dropped. Total residency never exceeds the configured
//!   capacity (shard capacities are floored so `shards * per_shard <=
//!   capacity`).
//! * **Counters**: lock-free hit/miss/insert/evict atomics, snapshotted
//!   by [`OrderingCache::stats`]; `hits + misses == lookups` always.
//!
//! Values are `Arc<Permutation>` so a hit is one atomic increment — the
//! caller, the cache, and an in-flight solve can all hold the same
//! ordering without copying the O(n) vector.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::engine::{reorderer, MatrixAnalysis};
use super::workspace::WorkspacePool;
use super::{Permutation, ReorderAlgorithm};
use crate::sparse::PatternKey;

/// Cache identity of one ordering: the structural fingerprint, which
/// algorithm ran, and the seed its randomness derived from.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct OrderingKey {
    pub pattern: PatternKey,
    pub algorithm: ReorderAlgorithm,
    pub seed: u64,
}

impl OrderingKey {
    /// The canonical key for an ordering of an analyzed matrix — every
    /// cache consumer builds keys through here, so the keying policy
    /// (fingerprint of the *symmetrized adjacency*, not the raw matrix)
    /// lives in one place.
    pub fn for_analysis(
        analysis: &MatrixAnalysis,
        algorithm: ReorderAlgorithm,
        seed: u64,
    ) -> OrderingKey {
        OrderingKey {
            pattern: analysis.pattern_key(),
            algorithm,
            seed,
        }
    }

    /// 64-bit mix used for shard selection (the pattern hash already has
    /// full entropy; fold in the algorithm and seed).
    fn mix(&self) -> u64 {
        let alg = self.algorithm as u64;
        let mut h = self
            .pattern
            .hash
            .wrapping_mul(0x9E3779B97F4A7C15)
            .rotate_left(17);
        h ^= alg.wrapping_mul(0xBF58476D1CE4E5B9);
        h ^= self.seed.wrapping_mul(0x94D049BB133111EB);
        h
    }
}

/// Sizing knobs for [`OrderingCache`].
#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    /// Maximum resident permutations across all shards.
    pub capacity: usize,
    /// Number of independently-locked shards (clamped to `capacity`).
    pub shards: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            capacity: 256,
            shards: 8,
        }
    }
}

/// Counter snapshot (one consistent read of the atomics).
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    /// Resident entries at snapshot time.
    pub entries: usize,
}

impl CacheStats {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        let l = self.lookups();
        if l == 0 {
            0.0
        } else {
            self.hits as f64 / l as f64
        }
    }
}

struct Entry {
    perm: Arc<Permutation>,
    /// Global tick of the last hit/insert (the LRU-ish recency stamp).
    last_used: u64,
}

/// Bounded, sharded `(PatternKey, algorithm, seed) → Arc<Permutation>`
/// map with LRU-ish eviction. See the module docs for the design.
pub struct OrderingCache {
    shards: Vec<Mutex<HashMap<OrderingKey, Entry>>>,
    per_shard: usize,
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    inserts: AtomicU64,
    evictions: AtomicU64,
}

impl OrderingCache {
    pub fn new(cfg: CacheConfig) -> Self {
        let capacity = cfg.capacity.max(1);
        let shards = cfg.shards.clamp(1, capacity);
        // floor division: shards * per_shard <= capacity, so the bound
        // the eviction test asserts holds exactly
        let per_shard = (capacity / shards).max(1);
        OrderingCache {
            shards: (0..shards).map(|_| Mutex::new(HashMap::new())).collect(),
            per_shard,
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            inserts: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    pub fn with_default_config() -> Self {
        Self::new(CacheConfig::default())
    }

    /// Effective capacity (`shards * per_shard`, ≤ the configured one).
    pub fn capacity(&self) -> usize {
        self.shards.len() * self.per_shard
    }

    /// Resident entries (sums shard sizes; momentary under concurrency).
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().expect("cache shard poisoned").len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn shard(&self, key: &OrderingKey) -> &Mutex<HashMap<OrderingKey, Entry>> {
        let i = (key.mix() % self.shards.len() as u64) as usize;
        &self.shards[i]
    }

    fn next_tick(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::Relaxed)
    }

    /// Counted lookup: `Some` stamps recency and counts a hit, `None`
    /// counts a miss.
    pub fn get(&self, key: &OrderingKey) -> Option<Arc<Permutation>> {
        let mut shard = self.shard(key).lock().expect("cache shard poisoned");
        match shard.get_mut(key) {
            Some(e) => {
                e.last_used = self.next_tick();
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(e.perm.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (idempotent: an existing entry for `key` is kept — the
    /// value is a pure function of the key, so both are identical and
    /// keeping the resident one preserves its recency). Evicts the
    /// stalest entry of the target shard when it is full.
    pub fn insert(&self, key: OrderingKey, perm: Arc<Permutation>) -> Arc<Permutation> {
        let mut shard = self.shard(&key).lock().expect("cache shard poisoned");
        if let Some(e) = shard.get(&key) {
            return e.perm.clone();
        }
        if shard.len() >= self.per_shard {
            if let Some(stale) = shard
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k)
            {
                shard.remove(&stale);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        let tick = self.next_tick();
        shard.insert(
            key,
            Entry {
                perm: perm.clone(),
                last_used: tick,
            },
        );
        self.inserts.fetch_add(1, Ordering::Relaxed);
        perm
    }

    /// The serving primitive: one counted lookup; on miss, compute
    /// *outside* the shard lock and insert. Returns the permutation and
    /// whether this call was a hit. Two threads missing the same key
    /// concurrently both compute (deterministically identical values);
    /// the first insert wins and the loser adopts the resident `Arc`, so
    /// every caller still observes one canonical permutation.
    pub fn get_or_compute(
        &self,
        key: OrderingKey,
        compute: impl FnOnce() -> Permutation,
    ) -> (Arc<Permutation>, bool) {
        if let Some(p) = self.get(&key) {
            return (p, true);
        }
        let perm = self.insert(key, Arc::new(compute()));
        (perm, false)
    }

    /// The request-path composition of cache + pool, shared by the
    /// serving engine and the selection pipeline so the key construction
    /// and the checkout discipline live in exactly one place: one
    /// counted lookup keyed off the analysis fingerprint; on miss, the
    /// algorithm runs on a workspace checked out of `pool` — the
    /// checkout happens only on the miss path, so warm traffic never
    /// touches the pool.
    pub fn fetch_or_order(
        &self,
        analysis: &MatrixAnalysis,
        algorithm: ReorderAlgorithm,
        seed: u64,
        pool: &WorkspacePool,
    ) -> (Arc<Permutation>, bool) {
        let key = OrderingKey::for_analysis(analysis, algorithm, seed);
        self.get_or_compute(key, || {
            let mut ws = pool.checkout();
            reorderer(algorithm).order(analysis.graph(), &mut ws, seed)
        })
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: self.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(pattern_hash: u64, n: usize, alg: ReorderAlgorithm, seed: u64) -> OrderingKey {
        OrderingKey {
            pattern: PatternKey {
                n,
                nnz: 3 * n,
                hash: pattern_hash,
            },
            algorithm: alg,
            seed,
        }
    }

    #[test]
    fn miss_then_hit_round_trip() {
        let cache = OrderingCache::with_default_config();
        let k = key(0xABCD, 5, ReorderAlgorithm::Amd, 7);
        let (p1, hit1) = cache.get_or_compute(k, || Permutation::identity(5));
        assert!(!hit1);
        let (p2, hit2) = cache.get_or_compute(k, || panic!("must not recompute"));
        assert!(hit2);
        assert!(Arc::ptr_eq(&p1, &p2));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.inserts), (1, 1, 1));
        assert_eq!(s.lookups(), 2);
        assert_eq!(s.entries, 1);
    }

    #[test]
    fn distinct_algorithms_and_seeds_are_distinct_entries() {
        let cache = OrderingCache::with_default_config();
        let mut n_entries = 0;
        for alg in [ReorderAlgorithm::Amd, ReorderAlgorithm::Rcm] {
            for seed in [1u64, 2] {
                let (_, hit) =
                    cache.get_or_compute(key(9, 4, alg, seed), || Permutation::identity(4));
                assert!(!hit);
                n_entries += 1;
            }
        }
        assert_eq!(cache.len(), n_entries);
    }

    #[test]
    fn capacity_is_never_exceeded_and_evictions_count() {
        let cache = OrderingCache::new(CacheConfig {
            capacity: 6,
            shards: 3,
        });
        assert!(cache.capacity() <= 6);
        for i in 0..50u64 {
            cache.insert(
                key(i, 4, ReorderAlgorithm::Amd, 0),
                Arc::new(Permutation::identity(4)),
            );
            assert!(cache.len() <= cache.capacity(), "overflow at insert {i}");
        }
        let s = cache.stats();
        assert!(s.evictions > 0);
        assert_eq!(s.inserts, 50);
        assert_eq!(s.entries, cache.len());
    }

    #[test]
    fn lru_ish_keeps_the_recently_used_entry() {
        // single shard, capacity 2: touch A, insert C -> B (stale) evicted
        let cache = OrderingCache::new(CacheConfig {
            capacity: 2,
            shards: 1,
        });
        let (ka, kb, kc) = (
            key(1, 3, ReorderAlgorithm::Amd, 0),
            key(2, 3, ReorderAlgorithm::Amd, 0),
            key(3, 3, ReorderAlgorithm::Amd, 0),
        );
        cache.insert(ka, Arc::new(Permutation::identity(3)));
        cache.insert(kb, Arc::new(Permutation::identity(3)));
        assert!(cache.get(&ka).is_some()); // A is now most recent
        cache.insert(kc, Arc::new(Permutation::identity(3)));
        assert!(cache.get(&ka).is_some(), "recently-used entry evicted");
        assert!(cache.get(&kb).is_none(), "stale entry survived");
        assert!(cache.get(&kc).is_some());
    }

    #[test]
    fn insert_is_idempotent() {
        let cache = OrderingCache::with_default_config();
        let k = key(7, 4, ReorderAlgorithm::Nd, 3);
        let first = cache.insert(k, Arc::new(Permutation::identity(4)));
        let second = cache.insert(k, Arc::new(Permutation::identity(4)));
        assert!(Arc::ptr_eq(&first, &second));
        assert_eq!(cache.stats().inserts, 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn degenerate_configs_are_clamped() {
        let cache = OrderingCache::new(CacheConfig {
            capacity: 0,
            shards: 0,
        });
        assert_eq!(cache.capacity(), 1);
        let tiny = OrderingCache::new(CacheConfig {
            capacity: 2,
            shards: 16,
        });
        assert!(tiny.capacity() <= 2);
    }
}

//! Nested dissection ordering (George 1973), multilevel (METIS-style).
//!
//! Recursively: bisect the graph (multilevel heavy-edge coarsening + FM,
//! `graph::partition`), extract a vertex separator, order the two parts
//! first and the separator last. Leaf subgraphs below `LEAF_SIZE` are
//! ordered by local minimum degree — the same leaf strategy METIS'
//! `METIS_NodeND` uses (MMD on the leaves).

use super::engine::Reorderer;
use super::mindeg::{min_degree_in, Variant};
use super::workspace::Workspace;
use super::{seed_rng, Permutation, ReorderAlgorithm};
use crate::graph::partition::{bisect, vertex_separator};
use crate::graph::Graph;
use crate::util::rng::Rng;

/// Leaf threshold for pure ND (METIS stops dissecting around ~100).
const LEAF_SIZE: usize = 64;

/// Nested dissection with MD-ordered leaves.
pub fn nested_dissection(g: &Graph, rng: &mut Rng) -> Permutation {
    nested_dissection_in(g, rng, &mut Workspace::new())
}

/// [`nested_dissection`] on a reusable workspace: the MD leaf orderings
/// share one quotient-graph scratch across every leaf of the recursion.
pub fn nested_dissection_in(g: &Graph, rng: &mut Rng, ws: &mut Workspace) -> Permutation {
    dissection_with(g, rng, LEAF_SIZE, ws, &|sub, ws| {
        min_degree_in(sub, Variant::Exact, &mut ws.mindeg)
    })
}

/// Generic dissection driver, shared with the SCOTCH/PORD hybrids: leaf
/// subgraphs of size ≤ `leaf_size` are ordered by `leaf_order`, which
/// receives the shared workspace (so leaf orderers reuse its scratch).
pub fn dissection_with(
    g: &Graph,
    rng: &mut Rng,
    leaf_size: usize,
    ws: &mut Workspace,
    leaf_order: &dyn Fn(&Graph, &mut Workspace) -> Permutation,
) -> Permutation {
    let n = g.n_vertices();
    let mut order = Vec::with_capacity(n);
    let verts: Vec<usize> = (0..n).collect();
    recurse(g, &verts, rng, leaf_size, leaf_order, &mut order, 0, ws);
    debug_assert_eq!(order.len(), n);
    Permutation::from_order(&order)
}

fn order_leaf(
    g: &Graph,
    verts: &[usize],
    leaf_order: &dyn Fn(&Graph, &mut Workspace) -> Permutation,
    out: &mut Vec<usize>,
    ws: &mut Workspace,
) {
    let sub = g.subgraph_in_with(verts, &mut ws.nd_local, &mut ws.nd_edges);
    let p = leaf_order(&sub, ws);
    // subgraph vertex k is verts[k] — no separate id map needed
    for &local_old in &p.order() {
        out.push(verts[local_old]);
    }
}

#[allow(clippy::too_many_arguments)]
fn recurse(
    g: &Graph,
    verts: &[usize],
    rng: &mut Rng,
    leaf_size: usize,
    leaf_order: &dyn Fn(&Graph, &mut Workspace) -> Permutation,
    out: &mut Vec<usize>,
    depth: usize,
    ws: &mut Workspace,
) {
    if verts.len() <= leaf_size || depth > 64 {
        order_leaf(g, verts, leaf_order, out, ws);
        return;
    }
    // the induced-edge buffer is workspace-owned and shared by every
    // level of the recursion (cleared per call, reused across calls)
    let sub = g.subgraph_in_with(verts, &mut ws.nd_local, &mut ws.nd_edges);
    let b = bisect(&sub, rng);
    let (sep, a, bb) = vertex_separator(&sub, &b.side);
    // Degenerate bisection (e.g. a clique where one side swallowed
    // everything): fall back to leaf ordering to guarantee progress.
    if a.is_empty() && bb.is_empty() {
        order_leaf(g, verts, leaf_order, out, ws);
        return;
    }
    if sep.is_empty() && (a.is_empty() || bb.is_empty()) {
        order_leaf(g, verts, leaf_order, out, ws);
        return;
    }
    let to_global = |locals: &[usize]| locals.iter().map(|&l| verts[l]).collect::<Vec<_>>();
    let ga = to_global(&a);
    let gb = to_global(&bb);
    let gsep = to_global(&sep);
    if !ga.is_empty() {
        recurse(g, &ga, rng, leaf_size, leaf_order, out, depth + 1, ws);
    }
    if !gb.is_empty() {
        recurse(g, &gb, rng, leaf_size, leaf_order, out, depth + 1, ws);
    }
    // Separator vertices are eliminated last (they border both halves).
    // Order within the separator: by degree (small first) — a cheap local
    // minimum-degree pass over the separator clique.
    let mut s = gsep;
    s.sort_by_key(|&v| (g.degree(v), v));
    out.extend(s);
}

/// Nested dissection as a plan-phase [`Reorderer`] (the only randomness
/// is the bisection's, seeded per run exactly like the legacy path).
pub struct NestedDissection;

impl Reorderer for NestedDissection {
    fn algorithm(&self) -> ReorderAlgorithm {
        ReorderAlgorithm::Nd
    }

    fn order(&self, g: &Graph, ws: &mut Workspace, seed: u64) -> Permutation {
        let mut rng = seed_rng(seed);
        nested_dissection_in(g, &mut rng, ws)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reorder::metrics;
    use crate::reorder::ReorderAlgorithm;
    use crate::sparse::CooMatrix;
    use crate::util::prop;

    fn grid_matrix(nx: usize, ny: usize) -> crate::sparse::CsrMatrix {
        let idx = |x: usize, y: usize| y * nx + x;
        let n = nx * ny;
        let mut coo = CooMatrix::new(n, n);
        for y in 0..ny {
            for x in 0..nx {
                let v = idx(x, y);
                coo.push(v, v, 4.0);
                if x + 1 < nx {
                    coo.push_sym(v, idx(x + 1, y), -1.0);
                }
                if y + 1 < ny {
                    coo.push_sym(v, idx(x, y + 1), -1.0);
                }
            }
        }
        coo.to_csr()
    }

    #[test]
    fn nd_valid_permutation_on_grid() {
        let a = grid_matrix(15, 15);
        let g = Graph::from_matrix(&a);
        let mut rng = Rng::new(1);
        let p = nested_dissection(&g, &mut rng);
        assert_eq!(p.len(), 225);
    }

    #[test]
    fn nd_reduces_fill_vs_natural_on_grid() {
        // George's theorem: ND fill on an s×s grid is O(n log n) vs the
        // natural (banded) ordering's O(n^{1.5}).
        let a = grid_matrix(20, 20);
        let g = Graph::from_matrix(&a);
        let mut rng = Rng::new(2);
        let p = nested_dissection(&g, &mut rng);
        let nd_fill = metrics::symbolic_fill(&a, &p);
        let nat_fill = metrics::symbolic_fill(&a, &Permutation::identity(400));
        assert!(
            nd_fill < nat_fill,
            "nd {nd_fill} >= natural {nat_fill}"
        );
    }

    #[test]
    fn nd_competitive_with_amd_on_large_grid() {
        let a = grid_matrix(24, 24);
        let g = Graph::from_matrix(&a);
        let mut rng = Rng::new(3);
        let nd_fill = metrics::symbolic_fill(&a, &nested_dissection(&g, &mut rng));
        let amd = ReorderAlgorithm::Amd.compute(&a, 1);
        let amd_fill = metrics::symbolic_fill(&a, &amd);
        // On 2D meshes ND should be within ~2x of AMD (often better).
        assert!(
            (nd_fill as f64) < 2.0 * amd_fill as f64,
            "nd {nd_fill} vs amd {amd_fill}"
        );
    }

    #[test]
    fn nd_handles_disconnected() {
        let g = Graph::from_edges(200, &(0..99).map(|i| (i, i + 1)).collect::<Vec<_>>());
        let mut rng = Rng::new(4);
        let p = nested_dissection(&g, &mut rng);
        assert_eq!(p.len(), 200);
    }

    #[test]
    fn nd_handles_clique() {
        // Worst case for bisection: complete graph — must still terminate.
        let n = 90;
        let mut edges = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                edges.push((i, j));
            }
        }
        let g = Graph::from_edges(n, &edges);
        let mut rng = Rng::new(5);
        let p = nested_dissection(&g, &mut rng);
        assert_eq!(p.len(), n);
    }

    #[test]
    fn prop_nd_valid_on_random_connected() {
        prop::check("nd-valid", 15, |rng_p| {
            let n = rng_p.range(10, 200);
            let edges = prop::random_connected_edges(rng_p, n, 0.02);
            let g = Graph::from_edges(n, &edges);
            let mut rng = Rng::new(rng_p.next_u64());
            let p = nested_dissection(&g, &mut rng);
            assert_eq!(p.len(), n);
        });
    }
}

//! Analysis / plan / execute: the shared-analysis reordering engine.
//!
//! * **Analyze** — [`MatrixAnalysis::of`] symmetrizes the matrix pattern
//!   once into the adjacency [`Graph`], reads off the vertex degrees
//!   (shared with `features::extract_with_degrees` so the classifier's
//!   feature pass and the ordering sweep pay one symmetrization), and
//!   lazily labels connected components.
//! * **Plan** — each algorithm is a [`Reorderer`]: a stateless strategy
//!   that turns the analysis into a [`Permutation`] using a caller-owned
//!   [`Workspace`] for all O(n) scratch.
//! * **Execute** — [`ReorderEngine::sweep`] runs many candidate
//!   orderings over the in-tree thread pool, one warm workspace per
//!   worker; [`ReorderEngine::sweep_map`] additionally times each
//!   ordering and pipes it straight into a caller continuation (the
//!   dataset sweep factorizes there, the benches record there).
//!
//! Permutations are bit-identical to the legacy
//! `ReorderAlgorithm::compute` path: the graph is the same
//! symmetrization, each algorithm derives its RNG from the same
//! `seed ^ 0x5ee_d`, and workspace reuse is observation-free (property
//! tested in `tests/prop_reorder_engine.rs`).

use std::sync::{Arc, OnceLock};

use super::cache::{OrderingCache, OrderingKey};
use super::workspace::Workspace;
use super::{hybrid, mindeg, nd, rcm, Permutation, ReorderAlgorithm};
use crate::graph::Graph;
use crate::sparse::{CsrMatrix, PatternKey};
use crate::util::pool::parallel_map_init;
use crate::util::Timer;

/// Everything the ordering layer derives from a matrix exactly once:
/// the symmetrized adjacency, its degrees, (on demand) connected
/// components, and (on demand) the structural fingerprint the ordering
/// cache keys on. Shared by every candidate ordering of a sweep and by
/// the feature extractor.
pub struct MatrixAnalysis {
    graph: Graph,
    degrees: Vec<usize>,
    components: OnceLock<(Vec<usize>, usize)>,
    key: OnceLock<PatternKey>,
}

impl MatrixAnalysis {
    /// Analyze a square matrix (one symmetrization, O(nnz)).
    pub fn of(a: &CsrMatrix) -> Self {
        Self::from_graph(Graph::from_matrix(a))
    }

    /// Wrap a prebuilt adjacency graph.
    pub fn from_graph(graph: Graph) -> Self {
        let degrees = graph.degrees();
        MatrixAnalysis {
            graph,
            degrees,
            components: OnceLock::new(),
            key: OnceLock::new(),
        }
    }

    pub fn n(&self) -> usize {
        self.graph.n_vertices()
    }

    /// The symmetrized adjacency every ordering consumes.
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// Vertex degrees of the symmetrized pattern — identical to
    /// `sparse::pattern::symmetrized_degrees` of the originating matrix,
    /// so `features::extract_with_degrees` can reuse them verbatim.
    pub fn degrees(&self) -> &[usize] {
        &self.degrees
    }

    /// Connected components (computed on first use, then cached):
    /// `(component id per vertex, component count)`.
    pub fn components(&self) -> (&[usize], usize) {
        let c = self.components.get_or_init(|| self.graph.components());
        (&c.0, c.1)
    }

    /// Fingerprint of the symmetrized adjacency (computed on first use,
    /// then cached). This — not the raw matrix's fingerprint — is what
    /// orderings are keyed on: every ordering is a pure function of the
    /// symmetrized graph, so matrices that symmetrize identically share
    /// cache entries.
    pub fn pattern_key(&self) -> PatternKey {
        *self.key.get_or_init(|| {
            PatternKey::of_parts(
                self.graph.n_vertices(),
                &self.graph.indptr,
                &self.graph.indices,
            )
        })
    }
}

/// A reordering strategy in the plan phase: stateless, scratch-free
/// (all O(n) working memory lives in the caller's [`Workspace`]), and
/// deterministic given `seed`.
pub trait Reorderer: Sync {
    /// Which [`ReorderAlgorithm`] this strategy implements.
    fn algorithm(&self) -> ReorderAlgorithm;

    /// Compute the ordering on the analyzed adjacency.
    fn order(&self, g: &Graph, ws: &mut Workspace, seed: u64) -> Permutation;
}

/// The no-op baseline.
struct Natural;

impl Reorderer for Natural {
    fn algorithm(&self) -> ReorderAlgorithm {
        ReorderAlgorithm::Natural
    }

    fn order(&self, g: &Graph, _ws: &mut Workspace, _seed: u64) -> Permutation {
        Permutation::identity(g.n_vertices())
    }
}

static NATURAL: Natural = Natural;
static CM: rcm::Cm = rcm::Cm;
static RCM: rcm::Rcm = rcm::Rcm;
static MD: mindeg::MinDeg = mindeg::MinDeg(mindeg::Variant::Exact);
static AMD: mindeg::MinDeg = mindeg::MinDeg(mindeg::Variant::Approximate);
static AMF: mindeg::MinDeg = mindeg::MinDeg(mindeg::Variant::MinFill);
static QAMD: mindeg::MinDeg = mindeg::MinDeg(mindeg::Variant::QuasiDense);
static ND: nd::NestedDissection = nd::NestedDissection;
static SCOTCH: hybrid::ScotchLike = hybrid::ScotchLike;
static PORD: hybrid::PordLike = hybrid::PordLike;

/// The [`Reorderer`] implementing a given algorithm.
pub fn reorderer(alg: ReorderAlgorithm) -> &'static dyn Reorderer {
    match alg {
        ReorderAlgorithm::Natural => &NATURAL,
        ReorderAlgorithm::Cm => &CM,
        ReorderAlgorithm::Rcm => &RCM,
        ReorderAlgorithm::Md => &MD,
        ReorderAlgorithm::Amd => &AMD,
        ReorderAlgorithm::Amf => &AMF,
        ReorderAlgorithm::Qamd => &QAMD,
        ReorderAlgorithm::Nd => &ND,
        ReorderAlgorithm::Scotch => &SCOTCH,
        ReorderAlgorithm::Pord => &PORD,
    }
}

/// Execute phase: run candidate orderings over one shared analysis,
/// concurrently over the in-tree pool, one warm [`Workspace`] per
/// worker. `workers == 1` degrades to an in-place sequential sweep —
/// the shape nested callers use (e.g. `dataset::build_dataset` already
/// runs one matrix per core, so its inner engine is pinned sequential
/// exactly like the dataset sweep pins the supernodal factorization).
pub struct ReorderEngine {
    workers: usize,
    cache: Option<Arc<OrderingCache>>,
}

impl ReorderEngine {
    pub fn new(workers: usize) -> Self {
        ReorderEngine {
            workers: workers.max(1),
            cache: None,
        }
    }

    /// Single-threaded engine (for nested contexts: the caller's pool
    /// already owns the cores).
    pub fn sequential() -> Self {
        Self::new(1)
    }

    /// Attach a pattern-keyed ordering cache: [`Self::compute`],
    /// [`Self::compute_shared`], and [`Self::sweep`]/[`Self::sweep_shared`]
    /// consult it before running an algorithm and publish what they
    /// compute. Hits are bit-identical to fresh computes (the cache key
    /// carries the pattern fingerprint, algorithm, and seed — everything
    /// an ordering is a function of).
    pub fn with_cache(mut self, cache: Arc<OrderingCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    pub fn cache(&self) -> Option<&Arc<OrderingCache>> {
        self.cache.as_ref()
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// One ordering on a caller-owned workspace (through the cache when
    /// one is attached; the hit path clones out of the shared entry —
    /// callers that can hold an `Arc` should prefer
    /// [`Self::compute_shared`], which doesn't copy).
    pub fn compute(
        &self,
        ma: &MatrixAnalysis,
        alg: ReorderAlgorithm,
        seed: u64,
        ws: &mut Workspace,
    ) -> Permutation {
        match &self.cache {
            None => reorderer(alg).order(ma.graph(), ws, seed),
            Some(_) => (*self.compute_shared(ma, alg, seed, ws).0).clone(),
        }
    }

    /// One ordering as a shared handle, plus whether it was a cache hit.
    /// Without a cache this is a fresh compute wrapped in an `Arc`
    /// (`hit == false`).
    pub fn compute_shared(
        &self,
        ma: &MatrixAnalysis,
        alg: ReorderAlgorithm,
        seed: u64,
        ws: &mut Workspace,
    ) -> (Arc<Permutation>, bool) {
        match &self.cache {
            None => (Arc::new(reorderer(alg).order(ma.graph(), ws, seed)), false),
            Some(cache) => {
                let key = OrderingKey::for_analysis(ma, alg, seed);
                let (perm, fetch) =
                    cache.get_or_compute(key, || reorderer(alg).order(ma.graph(), ws, seed));
                (perm, fetch.is_hit())
            }
        }
    }

    /// All candidate orderings, in input order (cache-aware when a cache
    /// is attached: hits skip the algorithm entirely).
    pub fn sweep(
        &self,
        ma: &MatrixAnalysis,
        algorithms: &[ReorderAlgorithm],
        seed: u64,
    ) -> Vec<Permutation> {
        match &self.cache {
            None => self.sweep_map(ma, algorithms, seed, |_, perm, _| perm),
            Some(_) => self
                .sweep_shared(ma, algorithms, seed)
                .into_iter()
                .map(|p| (*p).clone())
                .collect(),
        }
    }

    /// Cache-aware sweep returning shared handles: one counted cache
    /// lookup per candidate, misses computed over the pool with one warm
    /// workspace per worker, results in `algorithms` order.
    pub fn sweep_shared(
        &self,
        ma: &MatrixAnalysis,
        algorithms: &[ReorderAlgorithm],
        seed: u64,
    ) -> Vec<Arc<Permutation>> {
        parallel_map_init(
            algorithms,
            self.workers,
            Workspace::new,
            |ws, _, &alg| self.compute_shared(ma, alg, seed, ws).0,
        )
    }

    /// Sweep with a per-ordering continuation: `f(algorithm, permutation,
    /// reorder_seconds)` runs on the worker that computed the ordering
    /// (the dataset sweep factorizes+solves there, so the whole
    /// label-generation job for one matrix fans out over the pool).
    /// Results come back in `algorithms` order.
    ///
    /// Fair timing: when a worker will serve several candidates from one
    /// workspace, its scratch is warmed by an untimed throwaway ordering
    /// first, so the first timed candidate doesn't pay the cold O(n)
    /// buffer growth the later ones skip. With `workers >=
    /// algorithms.len()` every candidate gets a cold workspace
    /// (symmetric, like the legacy per-call path) and no warm-up runs.
    pub fn sweep_map<R, F>(
        &self,
        ma: &MatrixAnalysis,
        algorithms: &[ReorderAlgorithm],
        seed: u64,
        f: F,
    ) -> Vec<R>
    where
        R: Send,
        F: Fn(ReorderAlgorithm, Permutation, f64) -> R + Sync,
    {
        let warm = self.workers < algorithms.len();
        let init = || {
            let mut ws = Workspace::new();
            if warm {
                if let Some(&first) = algorithms.first() {
                    let _ = reorderer(first).order(ma.graph(), &mut ws, seed);
                }
            }
            ws
        };
        parallel_map_init(algorithms, self.workers, init, |ws, _, &alg| {
            let t = Timer::start();
            let perm = reorderer(alg).order(ma.graph(), ws, seed);
            let reorder_s = t.elapsed_s();
            f(alg, perm, reorder_s)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooMatrix;

    fn mesh(nx: usize, ny: usize) -> CsrMatrix {
        let idx = |x: usize, y: usize| y * nx + x;
        let n = nx * ny;
        let mut coo = CooMatrix::new(n, n);
        for y in 0..ny {
            for x in 0..nx {
                let v = idx(x, y);
                coo.push(v, v, 4.0);
                if x + 1 < nx {
                    coo.push_sym(v, idx(x + 1, y), -1.0);
                }
                if y + 1 < ny {
                    coo.push_sym(v, idx(x, y + 1), -1.0);
                }
            }
        }
        coo.to_csr()
    }

    #[test]
    fn analysis_shares_graph_and_degrees() {
        let a = mesh(8, 6);
        let ma = MatrixAnalysis::of(&a);
        let g = Graph::from_matrix(&a);
        assert_eq!(*ma.graph(), g);
        assert_eq!(ma.degrees(), &g.degrees()[..]);
        assert_eq!(
            ma.degrees(),
            &crate::sparse::pattern::symmetrized_degrees(&a)[..]
        );
        let (comp, k) = ma.components();
        assert_eq!(k, 1);
        assert_eq!(comp.len(), ma.n());
    }

    #[test]
    fn every_reorderer_reports_its_algorithm() {
        for alg in [
            ReorderAlgorithm::Natural,
            ReorderAlgorithm::Cm,
            ReorderAlgorithm::Rcm,
            ReorderAlgorithm::Md,
            ReorderAlgorithm::Amd,
            ReorderAlgorithm::Amf,
            ReorderAlgorithm::Qamd,
            ReorderAlgorithm::Nd,
            ReorderAlgorithm::Scotch,
            ReorderAlgorithm::Pord,
        ] {
            assert_eq!(reorderer(alg).algorithm(), alg);
        }
    }

    #[test]
    fn sweep_matches_legacy_compute() {
        let a = mesh(11, 9);
        let ma = MatrixAnalysis::of(&a);
        let engine = ReorderEngine::new(4);
        let perms = engine.sweep(&ma, &ReorderAlgorithm::PAPER_SET, 42);
        assert_eq!(perms.len(), ReorderAlgorithm::PAPER_SET.len());
        for (alg, perm) in ReorderAlgorithm::PAPER_SET.iter().zip(&perms) {
            assert_eq!(*perm, alg.compute(&a, 42), "{alg}");
        }
    }

    #[test]
    fn parallel_and_sequential_sweeps_agree() {
        let a = mesh(13, 13);
        let ma = MatrixAnalysis::of(&a);
        let par = ReorderEngine::new(8).sweep(&ma, &ReorderAlgorithm::PAPER_SET, 7);
        let seq = ReorderEngine::sequential().sweep(&ma, &ReorderAlgorithm::PAPER_SET, 7);
        assert_eq!(par, seq);
    }

    #[test]
    fn analysis_pattern_key_is_stable_and_symmetrization_canonical() {
        let a = mesh(7, 5);
        let ma = MatrixAnalysis::of(&a);
        assert_eq!(ma.pattern_key(), ma.pattern_key());
        // a matrix storing only one triangle symmetrizes to the same
        // adjacency, so it must share the ordering-cache key
        let mut coo = crate::sparse::CooMatrix::new(a.nrows, a.ncols);
        for r in 0..a.nrows {
            for (k, &c) in a.row_indices(r).iter().enumerate() {
                if c <= r {
                    coo.push(r, c, a.row_data(r)[k]);
                }
            }
        }
        let lower = coo.to_csr();
        assert!(lower.nnz() < a.nnz());
        assert_eq!(MatrixAnalysis::of(&lower).pattern_key(), ma.pattern_key());
    }

    #[test]
    fn cached_engine_matches_uncached_and_counts() {
        use crate::reorder::cache::{CacheConfig, OrderingCache};
        let a = mesh(9, 7);
        let ma = MatrixAnalysis::of(&a);
        let cache = std::sync::Arc::new(OrderingCache::new(CacheConfig::default()));
        let cached = ReorderEngine::new(4).with_cache(cache.clone());
        let plain = ReorderEngine::new(4);

        let first = cached.sweep(&ma, &ReorderAlgorithm::PAPER_SET, 42);
        let second = cached.sweep(&ma, &ReorderAlgorithm::PAPER_SET, 42);
        let fresh = plain.sweep(&ma, &ReorderAlgorithm::PAPER_SET, 42);
        assert_eq!(first, fresh);
        assert_eq!(second, fresh);

        let s = cache.stats();
        assert_eq!(s.misses, ReorderAlgorithm::PAPER_SET.len() as u64);
        assert_eq!(s.hits, ReorderAlgorithm::PAPER_SET.len() as u64);
        assert_eq!(s.lookups(), s.hits + s.misses);

        // compute() on a cached engine replays the same permutation
        let mut ws = Workspace::new();
        let one = cached.compute(&ma, ReorderAlgorithm::Amd, 42, &mut ws);
        assert_eq!(one, ReorderAlgorithm::Amd.compute(&a, 42));
        assert_eq!(cache.stats().hits, s.hits + 1);
    }

    #[test]
    fn compute_shared_without_cache_is_fresh() {
        let a = mesh(5, 5);
        let ma = MatrixAnalysis::of(&a);
        let engine = ReorderEngine::sequential();
        let mut ws = Workspace::new();
        let (p, hit) = engine.compute_shared(&ma, ReorderAlgorithm::Rcm, 7, &mut ws);
        assert!(!hit);
        assert_eq!(*p, ReorderAlgorithm::Rcm.compute(&a, 7));
    }

    #[test]
    fn sweep_map_times_and_orders_results() {
        let a = mesh(6, 6);
        let ma = MatrixAnalysis::of(&a);
        let engine = ReorderEngine::new(2);
        let out = engine.sweep_map(
            &ma,
            &ReorderAlgorithm::LABEL_SET,
            1,
            |alg, perm, reorder_s| {
                assert!(reorder_s >= 0.0);
                assert_eq!(perm.len(), 36);
                alg
            },
        );
        assert_eq!(out, ReorderAlgorithm::LABEL_SET.to_vec());
    }
}

//! Table-3 feature extraction: the 12 structural features the classifier
//! consumes.
//!
//! | # | feature    | description                      |
//! |---|------------|----------------------------------|
//! | 0 | dimension  | matrix dimension N               |
//! | 1 | nnz        | stored nonzeros                  |
//! | 2 | nnz_ratio  | nnz / N²                         |
//! | 3 | nnz_max    | max nonzeros per row             |
//! | 4 | nnz_min    | min nonzeros per row             |
//! | 5 | nnz_avg    | mean nonzeros per row            |
//! | 6 | nnz_std    | std of nonzeros per row          |
//! | 7 | degree_max | max node degree (A + Aᵀ graph)   |
//! | 8 | degree_min | min node degree                  |
//! | 9 | degree_avg | mean node degree                 |
//! |10 | bandwidth  | Eq. (2)                          |
//! |11 | profile    | Eq. (3)                          |
//!
//! Extraction is a single pass over the CSR structure plus a degree-only
//! sweep of the symmetrized pattern (`pattern::symmetrized_degrees` — no
//! adjacency graph or transpose is materialized, O(n) extra memory) —
//! this sits on the serving hot path in front of the MLP artifact, so it
//! is allocation-lean.

use crate::sparse::{pattern, CsrMatrix};

/// Number of features (must match `python/compile/model.py::N_FEATURES`).
pub const N_FEATURES: usize = 12;

/// Feature names in vector order (CSV headers, docs).
pub const FEATURE_NAMES: [&str; N_FEATURES] = [
    "dimension",
    "nnz",
    "nnz_ratio",
    "nnz_max",
    "nnz_min",
    "nnz_avg",
    "nnz_std",
    "degree_max",
    "degree_min",
    "degree_avg",
    "bandwidth",
    "profile",
];

/// The Table-3 feature vector of a square sparse matrix.
pub fn extract(a: &CsrMatrix) -> [f64; N_FEATURES] {
    let degrees = pattern::symmetrized_degrees(a);
    extract_with_degrees(a, &degrees)
}

/// [`extract`] with caller-supplied symmetrized degrees — bit-identical
/// output. `reorder::MatrixAnalysis::degrees` is exactly this vector, so
/// a pipeline that already analyzed the matrix for reordering shares the
/// symmetrization instead of re-deriving it here.
pub fn extract_with_degrees(a: &CsrMatrix, degrees: &[usize]) -> [f64; N_FEATURES] {
    assert_eq!(a.nrows, a.ncols, "features need a square matrix");
    assert_eq!(degrees.len(), a.nrows, "one degree per vertex");
    let n = a.nrows;
    let nnz = a.nnz();

    // per-row nnz moments in one pass (no per-row Vec allocation)
    let mut row_max = 0usize;
    let mut row_min = usize::MAX;
    let mut sum = 0f64;
    let mut sumsq = 0f64;
    for r in 0..n {
        let c = a.row_nnz(r);
        row_max = row_max.max(c);
        row_min = row_min.min(c);
        sum += c as f64;
        sumsq += (c * c) as f64;
    }
    if n == 0 {
        row_min = 0;
    }
    let nnz_avg = if n > 0 { sum / n as f64 } else { 0.0 };
    let nnz_var = if n > 0 {
        (sumsq / n as f64 - nnz_avg * nnz_avg).max(0.0)
    } else {
        0.0
    };

    // degrees of the symmetrized adjacency (computed by the caller —
    // either the degree-only sweep or a shared reorder analysis)
    let mut deg_max = 0usize;
    let mut deg_min = usize::MAX;
    let mut deg_sum = 0f64;
    for &d in degrees {
        deg_max = deg_max.max(d);
        deg_min = deg_min.min(d);
        deg_sum += d as f64;
    }
    if n == 0 {
        deg_min = 0;
    }

    [
        n as f64,
        nnz as f64,
        if n > 0 {
            nnz as f64 / (n as f64 * n as f64)
        } else {
            0.0
        },
        row_max as f64,
        row_min as f64,
        nnz_avg,
        nnz_var.sqrt(),
        deg_max as f64,
        deg_min as f64,
        if n > 0 { deg_sum / n as f64 } else { 0.0 },
        pattern::bandwidth(a) as f64,
        pattern::profile(a) as f64,
    ]
}

/// Batch extraction (one row per matrix).
pub fn extract_batch(mats: &[CsrMatrix]) -> Vec<[f64; N_FEATURES]> {
    mats.iter().map(extract).collect()
}

/// Per-column statistics of a feature matrix, used by the normalizers
/// (and exported into the MLP artifact's mean/std inputs).
#[derive(Clone, Debug)]
pub struct FeatureStats {
    pub mean: [f64; N_FEATURES],
    pub std: [f64; N_FEATURES],
    pub min: [f64; N_FEATURES],
    pub max: [f64; N_FEATURES],
}

impl FeatureStats {
    /// Streaming over rows, no per-column scratch. Accumulation order per
    /// feature is exactly the per-column order `stats::{mean, std_dev,
    /// min, max}` would see (Neumaier sum for the mean, naive
    /// squared-deviation sum for the variance, `f64::min`/`max` folds),
    /// so the results are bit-identical to the old column-copy version.
    pub fn compute(rows: &[[f64; N_FEATURES]]) -> FeatureStats {
        let mut mean = [0.0; N_FEATURES];
        let mut std = [0.0; N_FEATURES];
        let mut mn = [0.0; N_FEATURES];
        let mut mx = [0.0; N_FEATURES];
        if rows.is_empty() {
            return FeatureStats { mean, std, min: mn, max: mx };
        }

        // pass 1: Neumaier-compensated sums (see stats::sum) + min/max
        let mut s = [0.0f64; N_FEATURES];
        let mut c = [0.0f64; N_FEATURES];
        mn = [f64::INFINITY; N_FEATURES];
        mx = [f64::NEG_INFINITY; N_FEATURES];
        for row in rows {
            for f in 0..N_FEATURES {
                let x = row[f];
                let t = s[f] + x;
                if s[f].abs() >= x.abs() {
                    c[f] += (s[f] - t) + x;
                } else {
                    c[f] += (x - t) + s[f];
                }
                s[f] = t;
                mn[f] = mn[f].min(x);
                mx[f] = mx[f].max(x);
            }
        }
        let len = rows.len() as f64;
        for f in 0..N_FEATURES {
            mean[f] = (s[f] + c[f]) / len;
        }

        // pass 2: population variance around the pass-1 mean
        let mut sq = [0.0f64; N_FEATURES];
        for row in rows {
            for f in 0..N_FEATURES {
                sq[f] += (row[f] - mean[f]).powi(2);
            }
        }
        for f in 0..N_FEATURES {
            std[f] = (sq[f] / len).sqrt();
        }

        FeatureStats {
            mean,
            std,
            min: mn,
            max: mx,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooMatrix;

    fn band(n: usize, b: usize) -> CsrMatrix {
        let mut coo = CooMatrix::new(n, n);
        for i in 0..n {
            coo.push(i, i, 2.0);
            for d in 1..=b {
                if i + d < n {
                    coo.push_sym(i, i + d, -1.0);
                }
            }
        }
        coo.to_csr()
    }

    #[test]
    fn features_of_tridiagonal() {
        let a = band(10, 1);
        let f = extract(&a);
        assert_eq!(f[0], 10.0); // dimension
        assert_eq!(f[1], 28.0); // nnz = 10 + 2*9
        assert!((f[2] - 0.28).abs() < 1e-12);
        assert_eq!(f[3], 3.0); // max per row
        assert_eq!(f[4], 2.0); // min per row (end rows)
        assert!((f[5] - 2.8).abs() < 1e-12);
        assert_eq!(f[7], 2.0); // degree max
        assert_eq!(f[8], 1.0); // degree min
        assert_eq!(f[10], 1.0); // bandwidth
        assert_eq!(f[11], 9.0); // profile: rows 1..9 contribute 1 each
    }

    #[test]
    fn features_of_diagonal() {
        let a = CooMatrix::identity(5).to_csr();
        let f = extract(&a);
        assert_eq!(f[3], 1.0);
        assert_eq!(f[4], 1.0);
        assert_eq!(f[6], 0.0); // nnz_std
        assert_eq!(f[7], 0.0); // no off-diagonal -> degree 0
        assert_eq!(f[10], 0.0);
        assert_eq!(f[11], 0.0);
    }

    #[test]
    fn degree_counts_symmetrized() {
        // one directed entry still yields degree 1 on both endpoints
        let mut coo = CooMatrix::new(3, 3);
        coo.push(0, 2, 1.0);
        for i in 0..3 {
            coo.push(i, i, 1.0);
        }
        let f = extract(&coo.to_csr());
        assert_eq!(f[7], 1.0);
        assert_eq!(f[8], 0.0); // node 1 isolated
    }

    #[test]
    fn extract_with_shared_degrees_is_bit_identical() {
        use crate::reorder::MatrixAnalysis;
        for a in [band(10, 1), band(33, 4), band(7, 3)] {
            let ma = MatrixAnalysis::of(&a);
            assert_eq!(extract(&a), extract_with_degrees(&a, ma.degrees()));
        }
    }

    #[test]
    fn names_align_with_vector() {
        assert_eq!(FEATURE_NAMES.len(), N_FEATURES);
        assert_eq!(FEATURE_NAMES[10], "bandwidth");
    }

    #[test]
    fn stats_cover_columns() {
        let rows = vec![extract(&band(10, 1)), extract(&band(20, 2))];
        let st = FeatureStats::compute(&rows);
        assert!((st.mean[0] - 15.0).abs() < 1e-12);
        assert_eq!(st.min[0], 10.0);
        assert_eq!(st.max[0], 20.0);
        assert!(st.std[0] > 0.0);
    }

    #[test]
    fn stats_bit_identical_to_column_reference() {
        use crate::util::stats;
        let rows = vec![
            extract(&band(10, 1)),
            extract(&band(20, 2)),
            extract(&band(33, 4)),
            extract(&band(7, 3)),
        ];
        let st = FeatureStats::compute(&rows);
        for f in 0..N_FEATURES {
            let col: Vec<f64> = rows.iter().map(|r| r[f]).collect();
            // exact equality on purpose: the streaming pass must replay
            // the per-column accumulation order bit for bit
            assert_eq!(st.mean[f], stats::mean(&col), "mean[{f}]");
            assert_eq!(st.std[f], stats::std_dev(&col), "std[{f}]");
            assert_eq!(st.min[f], stats::min(&col), "min[{f}]");
            assert_eq!(st.max[f], stats::max(&col), "max[{f}]");
        }
    }

    #[test]
    fn stats_of_empty_rows_are_zero() {
        let st = FeatureStats::compute(&[]);
        assert_eq!(st.mean, [0.0; N_FEATURES]);
        assert_eq!(st.std, [0.0; N_FEATURES]);
        assert_eq!(st.min, [0.0; N_FEATURES]);
        assert_eq!(st.max, [0.0; N_FEATURES]);
    }

    #[test]
    fn batch_matches_single() {
        let mats = vec![band(8, 1), band(12, 3)];
        let batch = extract_batch(&mats);
        assert_eq!(batch[0], extract(&mats[0]));
        assert_eq!(batch[1], extract(&mats[1]));
    }

    #[test]
    fn empty_matrix_is_all_zero() {
        let a = CooMatrix::new(0, 0).to_csr();
        let f = extract(&a);
        assert!(f.iter().all(|&x| x == 0.0));
    }
}

//! Bench for paper Table 7: AMD-vs-predicted speedups on the largest
//! matrices (the Table-7 analogs), end-to-end with fresh measurement.
//! Run with `cargo bench --bench bench_table7`.

use smr::collection::paper_table7_analogs;
use smr::dataset::{sweep_one, SweepConfig};
use smr::reorder::ReorderAlgorithm;
use smr::util::bench::{fmt_time, section};

fn main() {
    section("Table 7 analogs: AMD vs best-label solution time");
    let cfg = SweepConfig::default();
    println!(
        "{:<20} {:>8} {:>12} {:>12} {:>9}",
        "matrix", "n", "AMD", "best", "speedup"
    );
    let mut speedups = Vec::new();
    for nm in paper_table7_analogs(42) {
        let rec = sweep_one(&nm, &ReorderAlgorithm::LABEL_SET, &cfg);
        let amd = rec.time_of(ReorderAlgorithm::Amd).unwrap();
        let best = rec.best();
        let speedup = amd / best.total_s.max(1e-12);
        speedups.push(speedup);
        println!(
            "{:<20} {:>8} {:>12} {:>12} {:>8.2}x",
            rec.name,
            rec.dimension,
            fmt_time(amd),
            fmt_time(best.total_s),
            speedup
        );
    }
    let avg = speedups.iter().sum::<f64>() / speedups.len() as f64;
    println!("average ideal speedup vs AMD on the largest analogs: {avg:.2}x");
}

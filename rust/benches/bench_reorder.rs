//! Reordering benchmarks: the legacy sequential per-algorithm path
//! (graph rebuilt + scratch reallocated per call) against the
//! analysis/plan/execute `ReorderEngine` (one `MatrixAnalysis`, warm
//! per-worker `Workspace`s, pool-parallel sweep).
//!
//! Run with `cargo bench --bench bench_reorder`. Besides the console
//! report it writes a machine-readable `BENCH_reorder.json` (override
//! the path with `BENCH_OUT`) so future PRs can diff the perf
//! trajectory: one record per matrix with the sequential 7-algorithm
//! sweep wall time, the engine-swept wall time, and the speedup, plus
//! per-algorithm warm-workspace timings.

use smr::collection::generators as g;
use smr::reorder::{MatrixAnalysis, ReorderAlgorithm, ReorderEngine, Workspace};
use smr::util::bench::{section, Bencher, JsonReport};
use smr::util::json;
use smr::util::pool;
use smr::util::rng::Rng;

const SEED: u64 = 42;

fn main() {
    let mut rng = Rng::new(1);
    let cases = vec![
        ("grid2d_48x48", g::grid2d(48, 48)),
        ("grid3d_12", g::grid3d(12, 12, 12)),
        ("scrambled_band_2000", g::scrambled_banded(2000, 4, &mut rng)),
        ("circuit_2000", g::circuit(2000, 4, &mut rng)),
        ("powerlaw_2000", g::powerlaw(2000, 3, &mut rng)),
    ];
    let workers = pool::default_workers();
    let engine = ReorderEngine::new(workers);

    let mut report = JsonReport::new();
    report.set("bench", json::s("bench_reorder"));
    report.set("workers", json::num(workers as f64));
    report.set("algorithms", json::num(ReorderAlgorithm::PAPER_SET.len() as f64));

    for (name, matrix) in &cases {
        section(&format!(
            "reorder sweep: {name} (n={}, nnz={})",
            matrix.nrows,
            matrix.nnz()
        ));
        let mut b = Bencher::new();

        // Legacy offline path: every algorithm re-symmetrizes the matrix
        // and allocates its own scratch — what `dataset::sweep_one` did
        // before the engine existed.
        let seq = b
            .bench(&format!("{name}/sweep7/sequential"), || {
                ReorderAlgorithm::PAPER_SET
                    .iter()
                    .map(|alg| alg.compute(matrix, SEED).len())
                    .sum::<usize>()
            })
            .clone();

        // Engine path: one analysis, pool-parallel sweep, one warm
        // workspace per worker. The analysis is built INSIDE the timed
        // closure so both sides pay their symmetrization cost (the
        // sequential baseline pays seven, a real engine sweep pays one).
        let eng = b
            .bench(&format!("{name}/sweep7/engine_x{workers}"), || {
                let analysis = MatrixAnalysis::of(matrix);
                engine.sweep(&analysis, &ReorderAlgorithm::PAPER_SET, SEED)
            })
            .clone();

        report.push(json::obj(vec![
            ("name", json::s(&format!("{name}/sweep7"))),
            ("n", json::num(matrix.nrows as f64)),
            ("nnz", json::num(matrix.nnz() as f64)),
            ("sequential_s", json::num(seq.min_s)),
            ("engine_s", json::num(eng.min_s)),
            (
                "speedup",
                json::num(seq.min_s / eng.min_s.max(1e-12)),
            ),
        ]));

        // Per-algorithm warm-workspace timings (shared analysis, reused
        // scratch — the per-candidate cost the engine sweep is built of).
        let analysis = MatrixAnalysis::of(matrix);
        let mut ws = Workspace::new();
        for alg in ReorderAlgorithm::PAPER_SET {
            let m = b
                .bench(&format!("{name}/{alg}/warm"), || {
                    alg.compute_with(analysis.graph(), SEED, &mut ws)
                })
                .clone();
            report.push(json::obj(vec![
                ("name", json::s(&format!("{name}/{alg}/warm"))),
                ("n", json::num(matrix.nrows as f64)),
                ("algorithm", json::s(alg.name())),
                ("wall_s", json::num(m.min_s)),
            ]));
        }
    }

    section("analysis construction");
    let big = g::grid2d(64, 64);
    let mut b = Bencher::new();
    let m = b
        .bench("MatrixAnalysis::of(grid 64x64)", || MatrixAnalysis::of(&big))
        .clone();
    report.push(json::obj(vec![
        ("name", json::s("analysis/grid2d_64x64")),
        ("n", json::num(big.nrows as f64)),
        ("wall_s", json::num(m.min_s)),
    ]));

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_reorder.json".into());
    match report.write(&out) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("\nfailed to write {out}: {e}"),
    }
}

//! Per-algorithm reordering benchmarks over representative structures.
//! Run with `cargo bench --bench bench_reorder`.

use smr::collection::generators as g;
use smr::graph::Graph;
use smr::reorder::ReorderAlgorithm;
use smr::util::bench::{section, Bencher};
use smr::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(1);
    let cases = vec![
        ("grid2d_48x48", g::grid2d(48, 48)),
        ("grid3d_12", g::grid3d(12, 12, 12)),
        ("scrambled_band_2000", g::scrambled_banded(2000, 4, &mut rng)),
        ("circuit_2000", g::circuit(2000, 4, &mut rng)),
        ("powerlaw_2000", g::powerlaw(2000, 3, &mut rng)),
    ];
    let algorithms = [
        ReorderAlgorithm::Rcm,
        ReorderAlgorithm::Md,
        ReorderAlgorithm::Amd,
        ReorderAlgorithm::Amf,
        ReorderAlgorithm::Qamd,
        ReorderAlgorithm::Nd,
        ReorderAlgorithm::Scotch,
        ReorderAlgorithm::Pord,
    ];
    for (name, matrix) in &cases {
        section(&format!(
            "reorder: {name} (n={}, nnz={})",
            matrix.nrows,
            matrix.nnz()
        ));
        let graph = Graph::from_matrix(matrix);
        let mut b = Bencher::new();
        for alg in algorithms {
            b.bench(&format!("{name}/{alg}"), || {
                alg.compute_on_graph(&graph, 42)
            });
        }
    }

    section("graph construction");
    let big = g::grid2d(64, 64);
    let mut b = Bencher::new();
    b.bench("Graph::from_matrix(grid 64x64)", || Graph::from_matrix(&big));
}

//! Feature-extraction benchmarks — this sits in front of every
//! prediction, so it must stay far below solve cost.
//! Run with `cargo bench --bench bench_features`.

use smr::collection::generators as g;
use smr::features;
use smr::util::bench::{section, Bencher};
use smr::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(1);
    let cases = vec![
        ("grid2d_32x32 (n=1k)", g::grid2d(32, 32)),
        ("grid2d_64x64 (n=4k)", g::grid2d(64, 64)),
        ("circuit_3000", g::circuit(3000, 5, &mut rng)),
        ("powerlaw_3000", g::powerlaw(3000, 4, &mut rng)),
        ("banded_5000", g::banded(5000, 10, &mut rng)),
    ];
    section("features::extract (12 Table-3 features)");
    let mut b = Bencher::new();
    for (name, m) in &cases {
        b.bench(&format!("extract/{name}"), || features::extract(m));
    }

    section("batch extraction");
    let batch: Vec<_> = (0..32).map(|k| g::grid2d(20 + k, 20)).collect();
    b.bench("extract_batch/32 grids", || features::extract_batch(&batch));
}

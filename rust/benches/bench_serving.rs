//! Serving-path benchmark: cold (plan-miss) vs warm (plan-hit) request
//! latency through the full `ServingEngine` path — matrix → features →
//! batched predict → cached plan → numeric solve.
//!
//! Run with `cargo bench --bench bench_serving`. Besides the console
//! report it writes a machine-readable `BENCH_serving.json` (override
//! the path with `BENCH_OUT`): one record per matrix with cold and warm
//! end-to-end latency, the warm speedup, and the warm **numeric-only**
//! latency (factor + triangular solves — all a warm request does after
//! prediction), plus the engine's symbolic-plan-cache and ordering-cache
//! hit/miss/evict counters and workspace / numeric-scratch pool
//! counters. A `batched` array records same-plan k-request bursts
//! served through `serve_batch` (batch latency, per-request
//! amortization, throughput), and a `batches` object snapshots the
//! engine's coalescing counters (groups formed, requests coalesced,
//! admission-window timeouts, group-size histogram). `ci.sh` validates
//! this artifact's schema (via `examples/check_bench`) whenever it is
//! present.

use smr::collection::generate_mini_collection;
use smr::coordinator::service::Backend;
use smr::coordinator::{ServingConfig, ServingEngine};
use smr::dataset::{build_dataset, SweepConfig};
use smr::ml::forest::{ForestParams, RandomForest};
use smr::ml::normalize::{Method, Normalizer};
use smr::ml::Classifier;
use smr::reorder::ReorderAlgorithm;
use smr::util::bench::{section, Bencher, JsonReport};
use smr::util::json;
use smr::util::Timer;

fn main() {
    // Train a forest backend on a small labeled sweep (pure Rust: the
    // bench needs no AOT artifacts).
    section("setup: sweep + train forest backend");
    let train_coll = generate_mini_collection(5, 2);
    let ds = build_dataset(
        &train_coll,
        &ReorderAlgorithm::LABEL_SET,
        &SweepConfig::default(),
    );
    let normalizer = Normalizer::fit(Method::Standard, &ds.features());
    let mut forest = RandomForest::new(
        ForestParams {
            n_estimators: 30,
            ..Default::default()
        },
        5,
    );
    forest.fit(&normalizer.transform(&ds.features()), &ds.labels(), 4);

    let cfg = ServingConfig::default();
    let engine = ServingEngine::spawn(Backend::Forest { normalizer, forest }, cfg)
        .expect("serving engine spawns");

    let mut report = JsonReport::new();
    report.set("bench", json::s("bench_serving"));
    report.set("cache_capacity", json::num(engine.cache().capacity() as f64));
    report.set(
        "plan_cache_capacity",
        json::num(engine.plans().capacity() as f64),
    );

    // Serve a distinct request mix (different seed than training).
    let serve_coll = generate_mini_collection(17, 2);
    for nm in &serve_coll {
        section(&format!(
            "serve: {} (n={}, nnz={})",
            nm.name,
            nm.matrix.nrows,
            nm.matrix.nnz()
        ));
        // Cold: first-ever request for this pattern (one shot — a cold
        // miss only exists once per pattern).
        let t = Timer::start();
        let cold_report = engine.serve(&nm.matrix).expect("cold request serves");
        let cold_s = t.elapsed_s();
        assert!(!cold_report.plan_hit, "{}: cold request hit", nm.name);

        // Warm: steady-state repeats of the identical request. Every
        // one must replay the cached plan (numeric-only); the
        // numeric-only column is the min over the same iterations that
        // produce warm_s, so the two stay noise-consistent. The cold
        // request above already sized the front arena for this pattern,
        // so the whole warm window must be allocation-free for fronts —
        // `warm_alloc_free` is the arena grow counter staying flat.
        let grows_before = smr::solver::arena::grow_events();
        let mut numeric_only_s = f64::INFINITY;
        let mut b = Bencher::coarse();
        let warm = b
            .bench(&format!("{}/warm", nm.name), || {
                let r = engine.serve(&nm.matrix).expect("warm request serves");
                assert!(r.plan_hit, "warm request missed the plan cache");
                numeric_only_s = numeric_only_s.min(r.numeric_s());
                r
            })
            .clone();
        let warm_alloc_free = smr::solver::arena::grow_events() == grows_before;
        println!(
            "    cold {:.3} ms -> warm {:.3} ms ({:.1}x) | numeric-only {:.3} ms | alloc-free {}",
            cold_s * 1e3,
            warm.min_s * 1e3,
            cold_s / warm.min_s.max(1e-12),
            numeric_only_s * 1e3,
            warm_alloc_free,
        );

        report.push(json::obj(vec![
            ("name", json::s(&nm.name)),
            ("n", json::num(nm.matrix.nrows as f64)),
            ("nnz", json::num(nm.matrix.nnz() as f64)),
            ("cold_s", json::num(cold_s)),
            ("warm_s", json::num(warm.min_s)),
            ("speedup", json::num(cold_s / warm.min_s.max(1e-12))),
            ("numeric_only_s", json::num(numeric_only_s)),
            ("warm_alloc_free", json::b(warm_alloc_free)),
        ]));
    }

    // Batched warm path: same-pattern, value-distinct bursts through
    // `serve_batch`, which coalesces each burst into ONE k-wide
    // traversal of the shared plan. Records land in a separate
    // top-level `batched` array (they carry batch columns, not the
    // cold/warm pair) with per-request amortization against this
    // pattern's single-request warm minimum.
    section("serve_batch: same-plan k-request bursts");
    let nm = &serve_coll[0];
    let mut single_warm = f64::INFINITY;
    {
        let mut b = Bencher::coarse();
        b.bench(&format!("{}/warm_single", nm.name), || {
            let t = Timer::start();
            let r = engine.serve(&nm.matrix).expect("warm request serves");
            single_warm = single_warm.min(t.elapsed_s());
            r
        });
    }
    let variants: Vec<_> = (0..8)
        .map(|l| {
            let mut m = nm.matrix.clone();
            for v in m.data.iter_mut() {
                *v *= 1.0 + 0.0625 * l as f64;
            }
            m
        })
        .collect();
    let mut batched_records = Vec::new();
    for k in [2usize, 4, 8] {
        let mats: Vec<_> = variants[..k].iter().collect();
        // warm-up burst: sizes the k-wide front arenas once
        engine.serve_batch(&mats).expect("batched requests serve");
        let mut b = Bencher::coarse();
        let m = b
            .bench(&format!("{}/batched_k{k}", nm.name), || {
                let rs = engine.serve_batch(&mats).expect("batched requests serve");
                assert!(
                    rs.iter().all(|r| r.plan_hit && r.batch_k == k),
                    "burst must coalesce into one k-wide group"
                );
                rs
            })
            .clone();
        let per_request_s = m.min_s / k as f64;
        println!(
            "    k={k}: {:.3} ms/batch = {:.3} ms/request ({:.1}x vs single warm)",
            m.min_s * 1e3,
            per_request_s * 1e3,
            single_warm / per_request_s.max(1e-12),
        );
        batched_records.push(json::obj(vec![
            ("name", json::s(&format!("{}/batched_k{k}", nm.name))),
            ("n", json::num(nm.matrix.nrows as f64)),
            ("nnz", json::num(nm.matrix.nnz() as f64)),
            ("batch_k", json::num(k as f64)),
            ("batch_s", json::num(m.min_s)),
            ("per_request_s", json::num(per_request_s)),
            ("throughput_per_s", json::num(k as f64 / m.min_s.max(1e-12))),
            (
                "speedup_vs_single",
                json::num(single_warm / per_request_s.max(1e-12)),
            ),
        ]));
    }
    report.set("batched", json::arr(batched_records));

    // Global per-stage counters.
    let stats = engine.stats();
    section("serving stats");
    println!(
        "requests {}  plans {} hits / {} misses / {} evictions (hit rate {:.1}%)",
        stats.requests,
        stats.plans.hits,
        stats.plans.misses,
        stats.plans.evictions,
        100.0 * stats.plans.hit_rate()
    );
    println!(
        "orderings: hits {} / misses {} | workspaces: checkouts {} creates {} reuses {} | \
         numeric scratch: checkouts {} creates {} | predict batches {} (mean size {:.1})",
        stats.cache.hits,
        stats.cache.misses,
        stats.workspaces.checkouts,
        stats.workspaces.creates,
        stats.workspaces.reuses,
        stats.numeric.checkouts,
        stats.numeric.creates,
        stats.service.batches,
        stats.service.mean_batch_size
    );
    let hist: Vec<String> = stats
        .batches
        .size_hist
        .iter()
        .enumerate()
        .map(|(i, c)| format!("{}:{c}", i + 1))
        .collect();
    println!(
        "solve batches: {} formed / {} requests coalesced / {} window timeouts | size hist {{{}}}",
        stats.batches.batches,
        stats.batches.coalesced,
        stats.batches.window_timeouts,
        hist.join(" "),
    );
    report.set(
        "plans",
        json::obj(vec![
            ("hits", json::num(stats.plans.hits as f64)),
            ("misses", json::num(stats.plans.misses as f64)),
            ("inserts", json::num(stats.plans.inserts as f64)),
            ("evictions", json::num(stats.plans.evictions as f64)),
            ("entries", json::num(stats.plans.entries as f64)),
            ("hit_rate", json::num(stats.plans.hit_rate())),
            ("leaders", json::num(stats.plans.leaders as f64)),
            ("coalesced", json::num(stats.plans.coalesced as f64)),
        ]),
    );
    report.set(
        "cache",
        json::obj(vec![
            ("hits", json::num(stats.cache.hits as f64)),
            ("misses", json::num(stats.cache.misses as f64)),
            ("inserts", json::num(stats.cache.inserts as f64)),
            ("evictions", json::num(stats.cache.evictions as f64)),
            ("entries", json::num(stats.cache.entries as f64)),
            ("hit_rate", json::num(stats.cache.hit_rate())),
            ("leaders", json::num(stats.cache.leaders as f64)),
            ("coalesced", json::num(stats.cache.coalesced as f64)),
        ]),
    );
    println!(
        "latency (e2e): p50 {:.3} ms  p99 {:.3} ms  p999 {:.3} ms over {} requests",
        stats.latency.e2e.p50() * 1e3,
        stats.latency.e2e.p99() * 1e3,
        stats.latency.e2e.p999() * 1e3,
        stats.latency.e2e.count,
    );
    report.set(
        "latency",
        json::obj(vec![
            ("count", json::num(stats.latency.e2e.count as f64)),
            ("p50_s", json::num(stats.latency.e2e.p50())),
            ("p99_s", json::num(stats.latency.e2e.p99())),
            ("p999_s", json::num(stats.latency.e2e.p999())),
            ("predict_p99_s", json::num(stats.latency.predict.p99())),
            ("plan_p99_s", json::num(stats.latency.plan.p99())),
            ("numeric_p99_s", json::num(stats.latency.numeric.p99())),
        ]),
    );
    report.set(
        "workspaces",
        json::obj(vec![
            ("checkouts", json::num(stats.workspaces.checkouts as f64)),
            ("creates", json::num(stats.workspaces.creates as f64)),
            ("reuses", json::num(stats.workspaces.reuses as f64)),
        ]),
    );
    report.set(
        "numeric_scratch",
        json::obj(vec![
            ("checkouts", json::num(stats.numeric.checkouts as f64)),
            ("creates", json::num(stats.numeric.creates as f64)),
            ("reuses", json::num(stats.numeric.reuses as f64)),
        ]),
    );
    println!(
        "front arenas: {} checkouts / {} creates / {} reuses | {} grow events",
        stats.fronts.arenas.checkouts,
        stats.fronts.arenas.creates,
        stats.fronts.arenas.reuses,
        stats.fronts.grows,
    );
    report.set(
        "fronts",
        json::obj(vec![
            ("checkouts", json::num(stats.fronts.arenas.checkouts as f64)),
            ("creates", json::num(stats.fronts.arenas.creates as f64)),
            ("reuses", json::num(stats.fronts.arenas.reuses as f64)),
            (
                "boundary_checkouts",
                json::num(stats.fronts.boundary.checkouts as f64),
            ),
            ("grows", json::num(stats.fronts.grows as f64)),
        ]),
    );
    report.set(
        "batches",
        json::obj(vec![
            ("batches", json::num(stats.batches.batches as f64)),
            ("coalesced", json::num(stats.batches.coalesced as f64)),
            (
                "window_timeouts",
                json::num(stats.batches.window_timeouts as f64),
            ),
            (
                "size_hist",
                json::arr(
                    stats
                        .batches
                        .size_hist
                        .iter()
                        .map(|&c| json::num(c as f64)),
                ),
            ),
        ]),
    );
    report.set("requests", json::num(stats.requests as f64));

    engine.shutdown();

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_serving.json".into());
    match report.write(&out) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("\nfailed to write {out}: {e}"),
    }
}

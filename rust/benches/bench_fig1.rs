//! Bench for paper Fig. 1: the 30-matrix × 4-algorithm normalized-time
//! sweep — measures how long regenerating the figure's data takes and
//! prints the heat rows. Run with `cargo bench --bench bench_fig1`.

use smr::collection::generate_mini_collection;
use smr::dataset::{build_dataset, SweepConfig};
use smr::experiments::fig1::shade;
use smr::reorder::ReorderAlgorithm;
use smr::util::bench::{section, Bencher};

fn main() {
    section("Fig. 1 data generation (30-matrix sweep)");
    let coll: Vec<_> = generate_mini_collection(3, 5)
        .into_iter()
        .take(30)
        .collect();
    let mut b = Bencher::coarse();
    b.bench("sweep 30 matrices x 4 algorithms", || {
        build_dataset(&coll, &ReorderAlgorithm::LABEL_SET, &SweepConfig::default())
    });

    // print one instance of the heatmap rows
    let ds = build_dataset(&coll, &ReorderAlgorithm::LABEL_SET, &SweepConfig::default());
    section("heat rows (AMD SCOTCH ND RCM; # fastest)");
    for rec in &ds.records {
        let times: Vec<f64> = ReorderAlgorithm::LABEL_SET
            .iter()
            .map(|a| rec.time_of(*a).unwrap())
            .collect();
        let mn = times.iter().copied().fold(f64::MAX, f64::min).max(1e-12);
        let heat: String = times.iter().map(|&t| shade(t / mn)).collect();
        println!("{:<22} {}", rec.name, heat);
    }
}

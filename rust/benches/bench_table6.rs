//! Bench for paper Table 6: the three-scenario comparison (always-AMD vs
//! predicted vs ideal) over a held-out split — regenerates the summary
//! and times the full evaluation. Run with `cargo bench --bench bench_table6`.

use smr::collection::generate_mini_collection;
use smr::coordinator::train_forest;
use smr::dataset::{build_dataset, SweepConfig};
use smr::ml::normalize::Method;
use smr::ml::Classifier;
use smr::reorder::ReorderAlgorithm;
use smr::util::bench::{section, Bencher};

fn main() {
    let coll = generate_mini_collection(11, 8);
    let ds = build_dataset(&coll, &ReorderAlgorithm::LABEL_SET, &SweepConfig::default());
    let (tr, te) = ds.split(0.8, 11);
    let tf = train_forest(&ds, &tr, Method::Standard, 11);
    let x = ds.features();

    section("Table 6 evaluation over the test split");
    let mut b = Bencher::new();
    let m = b.bench("evaluate 3 scenarios", || {
        let mut amd = 0.0;
        let mut pred = 0.0;
        let mut ideal = 0.0;
        for &i in &te {
            let rec = &ds.records[i];
            let label = Classifier::predict(&tf.forest, &tf.normalizer.transform_row(&x[i]));
            let alg = ReorderAlgorithm::LABEL_SET[label.min(3)];
            amd += rec.time_of(ReorderAlgorithm::Amd).unwrap();
            pred += rec.time_of(alg).unwrap();
            ideal += rec.best().total_s;
        }
        (amd, pred, ideal)
    });
    let _ = m;

    // print the actual summary once
    let mut amd = 0.0;
    let mut pred = 0.0;
    let mut ideal = 0.0;
    for &i in &te {
        let rec = &ds.records[i];
        let label = Classifier::predict(&tf.forest, &tf.normalizer.transform_row(&x[i]));
        let alg = ReorderAlgorithm::LABEL_SET[label.min(3)];
        amd += rec.time_of(ReorderAlgorithm::Amd).unwrap();
        pred += rec.time_of(alg).unwrap();
        ideal += rec.best().total_s;
    }
    println!(
        "summary: AMD {amd:.4}s | predicted {pred:.4}s ({:+.1}%) | ideal {ideal:.4}s",
        100.0 * (pred / amd - 1.0)
    );
}

//! Bench for paper Table 1: end-to-end solve time of each named analog
//! under the four label algorithms. Prints the table rows (one criterion
//! measurement per cell). Run with `cargo bench --bench bench_table1`.

use smr::collection::paper_table1_analogs;
use smr::reorder::ReorderAlgorithm;
use smr::solver::{prepare, solve_ordered, SolverConfig};
use smr::util::bench::{fmt_time, section};

fn main() {
    let cfg = SolverConfig {
        measure_repeats: 3,
        ..Default::default()
    };
    section("Table 1 regeneration (min-of-3 measured solution times)");
    println!(
        "{:<16} {:>12} {:>12} {:>12} {:>12}   best",
        "matrix", "AMD", "SCOTCH", "ND", "RCM"
    );
    for nm in paper_table1_analogs(42) {
        let spd = prepare(&nm.matrix, &cfg);
        let mut times = Vec::new();
        for alg in ReorderAlgorithm::LABEL_SET {
            let perm = alg.compute(&spd, 42);
            let r = solve_ordered(&spd, &perm, &cfg).unwrap();
            times.push(r.total_s());
        }
        let best = ReorderAlgorithm::LABEL_SET[times
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0];
        println!(
            "{:<16} {:>12} {:>12} {:>12} {:>12}   {}",
            nm.name,
            fmt_time(times[0]),
            fmt_time(times[1]),
            fmt_time(times[2]),
            fmt_time(times[3]),
            best.name()
        );
    }
}

//! Bench for paper Table 5's prediction-time column: single-prediction
//! latency (features + inference) and batched service throughput — for
//! both the Random-Forest backend and, when artifacts exist, the AOT MLP
//! through PJRT. Run with `cargo bench --bench bench_predict`.

use std::path::Path;
use std::sync::Arc;

use smr::collection::generate_mini_collection;
use smr::coordinator::service::Backend;
use smr::coordinator::{train_forest, BatcherConfig, PredictionService};
use smr::dataset::{build_dataset, SweepConfig};
use smr::features;
use smr::ml::normalize::Method;
use smr::ml::Classifier;
use smr::model::{MlpDriver, MlpModel};
use smr::reorder::ReorderAlgorithm;
use smr::runtime::{Manifest, Runtime};
use smr::util::bench::{section, Bencher};

fn main() {
    let coll = generate_mini_collection(3, 4);
    let ds = build_dataset(&coll, &ReorderAlgorithm::LABEL_SET, &SweepConfig::default());
    let (tr, _) = ds.split(0.8, 3);
    let tf = train_forest(&ds, &tr, Method::Standard, 3);
    let feats: Vec<Vec<f64>> = coll
        .iter()
        .map(|m| features::extract(&m.matrix).to_vec())
        .collect();

    section("prediction latency (features precomputed)");
    let mut b = Bencher::new();
    b.bench("forest predict x1", || {
        Classifier::predict(&tf.forest, &tf.normalizer.transform_row(&feats[0]))
    });

    section("feature extraction + predict (full Table-5 prediction path)");
    b.bench("features+predict (grid 32x32)", || {
        let f = features::extract(&coll[0].matrix);
        Classifier::predict(&tf.forest, &tf.normalizer.transform_row(&f))
    });

    // MLP through PJRT
    let artifacts = Path::new("artifacts");
    if artifacts.join("manifest.json").exists() {
        section("AOT MLP predict via PJRT (batch variants)");
        let runtime = Runtime::cpu().unwrap();
        let manifest = Manifest::load(artifacts).unwrap();
        let arch = manifest.archs().into_iter().next().unwrap();
        let meta = manifest
            .artifacts
            .iter()
            .find(|a| a.arch == arch)
            .unwrap();
        let model = MlpModel::init(&arch, meta.h1, meta.h2, 1);
        let driver = MlpDriver::new(&runtime, &manifest);
        // warm the executable cache
        let _ = driver.predict(&model, &feats[..1.min(feats.len())].to_vec());
        let mut b = Bencher::new();
        for batch in [1usize, 8, 64] {
            let xs: Vec<Vec<f64>> = (0..batch).map(|k| feats[k % feats.len()].clone()).collect();
            b.bench(&format!("mlp predict b{batch}"), || {
                driver.predict(&model, &xs).unwrap()
            });
        }
    } else {
        eprintln!("(artifacts missing: skipping MLP latency — run `make artifacts`)");
    }

    section("batched service throughput (forest backend)");
    let svc = Arc::new(
        PredictionService::spawn(
            Backend::Forest {
                normalizer: tf.normalizer,
                forest: tf.forest,
            },
            BatcherConfig::default(),
        )
        .unwrap(),
    );
    let mut b = Bencher::coarse();
    b.bench("256 concurrent predictions (8 clients)", || {
        let mut handles = Vec::new();
        for c in 0..8 {
            let svc = svc.clone();
            let f = feats[c % feats.len()].clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..32 {
                    svc.predict(&f).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });
    println!("mean service batch size: {:.2}", svc.stats.mean_batch_size());
}

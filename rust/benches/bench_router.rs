//! Traffic-replay benchmark for the shard-routed serving tier: Zipf
//! arrivals over a deterministic pattern population, replayed against
//! 1/2/4-replica [`ShardRouter`] fleets in closed- and open-loop modes.
//!
//! Run with `cargo bench --bench bench_router`. Writes
//! `BENCH_router.json` (override with `BENCH_OUT`): one record per
//! `(mode, replica count)` lane with throughput, fleet plan hit rate,
//! in-flight dedup counters (leaders vs coalesced — symbolic work saved
//! on cold stampedes), p50/p99/p999 end-to-end latency from the lane's
//! own log-bucketed histogram, and per-replica request counts plus
//! admission-gate occupancy high-water marks. `ci.sh` schema-gates the
//! artifact via `examples/check_bench` whenever it is present.
//!
//! * **Closed loop**: W worker threads pull the next trace entry as soon
//!   as their previous request completes — measures capacity (offered
//!   load adapts to service rate). Each worker is the "retrying client"
//!   the `Reject` policy presumes: on `Overloaded` it sleeps a seeded
//!   jittered-exponential delay (`util::backoff::Backoff`) and retries
//!   the same request, resetting on success — a `reject_r2` lane with a
//!   shallow gate exercises exactly that loop.
//! * **Open loop**: arrivals are scheduled at a fixed rate (70% of the
//!   measured closed-loop capacity) regardless of completions, and each
//!   request's latency is charged from its *scheduled* arrival — the
//!   coordinated-omission-free view of tail latency.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use smr::collection::generate_mini_collection;
use smr::collection::generators::pattern_population;
use smr::coordinator::service::Backend;
use smr::coordinator::{OverloadPolicy, RouterConfig, RouterError, ShardRouter};
use smr::dataset::{build_dataset, SweepConfig};
use smr::ml::forest::{ForestParams, RandomForest};
use smr::ml::normalize::{Method, Normalizer};
use smr::ml::Classifier;
use smr::reorder::ReorderAlgorithm;
use smr::sparse::CsrMatrix;
use smr::util::backoff::{Backoff, BackoffConfig};
use smr::util::bench::{section, JsonReport};
use smr::util::hist::LatencyHist;
use smr::util::json;
use smr::util::rng::{Rng, Zipf};
use smr::util::Timer;

const PATTERNS: usize = 24;
const ZIPF_S: f64 = 1.1;
const TRACE_LEN: usize = 400;
const WORKERS: usize = 4;
/// Retry budget per request before the closed-loop client sheds it.
const MAX_RETRIES: u32 = 12;

fn trained_backend() -> Backend {
    let train_coll = generate_mini_collection(5, 2);
    let ds = build_dataset(
        &train_coll,
        &ReorderAlgorithm::LABEL_SET,
        &SweepConfig::default(),
    );
    let normalizer = Normalizer::fit(Method::Standard, &ds.features());
    let mut forest = RandomForest::new(
        ForestParams {
            n_estimators: 30,
            ..Default::default()
        },
        5,
    );
    forest.fit(&normalizer.transform(&ds.features()), &ds.labels(), 4);
    Backend::Forest { normalizer, forest }
}

/// One lane's outcome, ready to serialize.
struct LaneResult {
    requests: u64,
    ok: u64,
    rejected: u64,
    /// Overload retries the closed-loop client absorbed via backoff
    /// (always 0 in open-loop lanes: scheduled arrivals don't retry).
    retries: u64,
    elapsed_s: f64,
    latency: smr::util::hist::HistSnapshot,
}

/// Closed loop: workers race down the shared trace index, each charging
/// latency from its own dispatch instant. Each worker carries its own
/// seeded [`Backoff`]: `Overloaded` sleeps a jittered-exponential delay
/// and retries the same request (latency still charged from first
/// dispatch — retries are not coordinated omission), success resets the
/// schedule, and after [`MAX_RETRIES`] the request is shed.
fn run_closed(router: &ShardRouter, trace: &[usize], pop: &[CsrMatrix]) -> LaneResult {
    let next = AtomicUsize::new(0);
    let hist = LatencyHist::new();
    let ok = AtomicUsize::new(0);
    let rejected = AtomicUsize::new(0);
    let retries = AtomicUsize::new(0);
    let t = Timer::start();
    std::thread::scope(|scope| {
        for w in 0..WORKERS {
            let (next, hist, ok, rejected, retries) = (&next, &hist, &ok, &rejected, &retries);
            scope.spawn(move || {
                let mut backoff = Backoff::new(BackoffConfig::default(), 0xB0FF ^ w as u64);
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= trace.len() {
                        break;
                    }
                    let t_req = Timer::start();
                    loop {
                        match router.serve(&pop[trace[i]]) {
                            Ok(_) => {
                                hist.record_s(t_req.elapsed_s());
                                ok.fetch_add(1, Ordering::Relaxed);
                                backoff.reset();
                                break;
                            }
                            Err(RouterError::Overloaded { .. })
                                if backoff.attempt() < MAX_RETRIES =>
                            {
                                retries.fetch_add(1, Ordering::Relaxed);
                                std::thread::sleep(backoff.next_delay());
                            }
                            Err(_) => {
                                rejected.fetch_add(1, Ordering::Relaxed);
                                backoff.reset();
                                break;
                            }
                        }
                    }
                }
            });
        }
    });
    LaneResult {
        requests: trace.len() as u64,
        ok: ok.load(Ordering::Relaxed) as u64,
        rejected: rejected.load(Ordering::Relaxed) as u64,
        retries: retries.load(Ordering::Relaxed) as u64,
        elapsed_s: t.elapsed_s(),
        latency: hist.snapshot(),
    }
}

/// Open loop: request `i` is *due* at `start + i/rate`; workers sleep
/// until the due time and charge latency from it, so queueing delay
/// behind a slow request is visible in the tail (no coordinated
/// omission).
fn run_open(router: &ShardRouter, trace: &[usize], pop: &[CsrMatrix], rate: f64) -> LaneResult {
    let next = AtomicUsize::new(0);
    let hist = LatencyHist::new();
    let ok = AtomicUsize::new(0);
    let rejected = AtomicUsize::new(0);
    let start = Instant::now();
    let interval_s = 1.0 / rate.max(1.0);
    std::thread::scope(|scope| {
        for _ in 0..WORKERS {
            let (next, hist, ok, rejected) = (&next, &hist, &ok, &rejected);
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= trace.len() {
                    break;
                }
                let due = Duration::from_secs_f64(i as f64 * interval_s);
                let now = start.elapsed();
                if due > now {
                    std::thread::sleep(due - now);
                }
                match router.serve(&pop[trace[i]]) {
                    Ok(_) => {
                        hist.record_s((start.elapsed() - due).as_secs_f64());
                        ok.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(_) => {
                        rejected.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });
    LaneResult {
        requests: trace.len() as u64,
        ok: ok.load(Ordering::Relaxed) as u64,
        rejected: rejected.load(Ordering::Relaxed) as u64,
        retries: 0,
        elapsed_s: start.elapsed().as_secs_f64(),
        latency: hist.snapshot(),
    }
}

fn lane_record(
    name: &str,
    mode: &str,
    replicas: usize,
    lane: &LaneResult,
    router: &ShardRouter,
) -> smr::util::json::Json {
    let s = router.stats();
    let per_replica: Vec<_> = s
        .replicas
        .iter()
        .enumerate()
        .map(|(i, r)| {
            json::obj(vec![
                ("replica", json::num(i as f64)),
                ("requests", json::num(r.requests as f64)),
                ("spill_in", json::num(r.spill_in as f64)),
                ("occupancy_hwm", json::num(r.gate.high_water as f64)),
            ])
        })
        .collect();
    println!(
        "    {name}: {:.1} req/s | p50 {:.3} ms p99 {:.3} ms p999 {:.3} ms | \
         hit rate {:.1}% | leaders {} coalesced {} | rejected {} retries {}",
        lane.ok as f64 / lane.elapsed_s.max(1e-12),
        lane.latency.p50() * 1e3,
        lane.latency.p99() * 1e3,
        lane.latency.p999() * 1e3,
        100.0 * s.plan_hit_rate(),
        s.plan_leaders(),
        s.plan_coalesced(),
        lane.rejected,
        lane.retries,
    );
    json::obj(vec![
        ("name", json::s(name)),
        ("mode", json::s(mode)),
        ("replicas", json::num(replicas as f64)),
        ("requests", json::num(lane.requests as f64)),
        ("ok", json::num(lane.ok as f64)),
        ("rejected", json::num(lane.rejected as f64)),
        ("retries", json::num(lane.retries as f64)),
        ("elapsed_s", json::num(lane.elapsed_s)),
        (
            "throughput_per_s",
            json::num(lane.ok as f64 / lane.elapsed_s.max(1e-12)),
        ),
        ("p50_s", json::num(lane.latency.p50())),
        ("p99_s", json::num(lane.latency.p99())),
        ("p999_s", json::num(lane.latency.p999())),
        ("mean_s", json::num(lane.latency.mean_s())),
        ("plan_hit_rate", json::num(s.plan_hit_rate())),
        ("leaders", json::num(s.plan_leaders() as f64)),
        ("coalesced", json::num(s.plan_coalesced() as f64)),
        ("spilled", json::num(s.spilled as f64)),
        ("per_replica", json::arr(per_replica)),
    ])
}

fn main() {
    section("setup: sweep + train forest backend");
    let backend = trained_backend();

    section(&format!(
        "setup: {PATTERNS}-pattern population, Zipf(s={ZIPF_S}) trace of {TRACE_LEN}"
    ));
    let pop = pattern_population(PATTERNS, 0xD1CE);
    let zipf = Zipf::new(PATTERNS, ZIPF_S);
    let mut rng = Rng::new(0x7AFF);
    let trace: Vec<usize> = (0..TRACE_LEN).map(|_| zipf.sample(&mut rng)).collect();

    let mut report = JsonReport::new();
    report.set("bench", json::s("bench_router"));
    report.set("patterns", json::num(PATTERNS as f64));
    report.set("zipf_s", json::num(ZIPF_S));
    report.set("trace_len", json::num(TRACE_LEN as f64));
    report.set("workers", json::num(WORKERS as f64));

    for replicas in [1usize, 2, 4] {
        section(&format!("replay: {replicas} replica(s)"));
        let router = ShardRouter::spawn(
            RouterConfig {
                replicas,
                queue_depth: 16,
                policy: OverloadPolicy::Block,
                ..Default::default()
            },
            |_| backend.clone(),
        )
        .expect("router spawns");

        // closed loop first: cold caches, measures capacity
        let closed = run_closed(&router, &trace, &pop);
        report.push(lane_record(
            &format!("closed_r{replicas}"),
            "closed",
            replicas,
            &closed,
            &router,
        ));

        // open loop on the now-warm fleet at 70% of measured capacity
        let capacity = closed.ok as f64 / closed.elapsed_s.max(1e-12);
        let rate = (0.7 * capacity).max(1.0);
        let open = run_open(&router, &trace, &pop, rate);
        let mut rec = lane_record(
            &format!("open_r{replicas}"),
            "open",
            replicas,
            &open,
            &router,
        );
        if let smr::util::json::Json::Obj(ref mut map) = rec {
            map.insert("offered_rate_per_s".to_string(), json::num(rate));
        }
        report.push(rec);

        router.shutdown();
    }

    // Reject policy with a shallow gate: the backpressure shape the
    // retrying client exists for. W workers over 2 seats guarantees
    // rejections; backoff absorbs them without lockstep retry storms.
    section("replay: 2 replicas, Reject policy, shallow gate (backoff client)");
    let router = ShardRouter::spawn(
        RouterConfig {
            replicas: 2,
            queue_depth: 2,
            policy: OverloadPolicy::Reject,
            ..Default::default()
        },
        |_| backend.clone(),
    )
    .expect("router spawns");
    let reject = run_closed(&router, &trace, &pop);
    report.push(lane_record("reject_r2", "closed", 2, &reject, &router));
    router.shutdown();

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_router.json".into());
    match report.write(&out) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("\nfailed to write {out}: {e}"),
    }
}

//! Incremental-replanning benchmark: plan **repair** latency vs cold
//! symbolic re-analysis across drift sizes, plus a drifting-pattern
//! serving trace with the three-tier lookup counters.
//!
//! Run with `cargo bench --bench bench_replan`. Writes a
//! machine-readable `BENCH_replan.json` (override with `BENCH_OUT`):
//! one record per drift size with the cold re-analysis latency, the
//! repair latency (diff + repair, the whole near-match tier cost), and
//! the speedup; plus a `serving` object with the engine's repair
//! counters over a Newton-like drifting trace — `repairs`,
//! `repair_fallbacks`, hits/misses, and the repair rate over drift
//! steps, proving the tier resolved the drift (no silent fallback).
//! `ci.sh` validates this artifact's schema (via `examples/check_bench`)
//! whenever it is present.

use std::sync::Arc;

use smr::collection::generate_mini_collection;
use smr::collection::generators::grid2d;
use smr::coordinator::service::Backend;
use smr::coordinator::{ServingConfig, ServingEngine};
use smr::dataset::{build_dataset, SweepConfig};
use smr::ml::forest::{ForestParams, RandomForest};
use smr::ml::normalize::{Method, Normalizer};
use smr::ml::Classifier;
use smr::reorder::ReorderAlgorithm;
use smr::solver::{plan_solve, prepare, RepairConfig, SolverConfig};
use smr::sparse::{CooMatrix, CsrMatrix};
use smr::util::bench::{section, Bencher, JsonReport};
use smr::util::json;
use smr::util::Timer;

/// Drift `a` by `k` new entries among the first two grid rows — leaf
/// vertices under the natural ordering (eliminated long before the top
/// of the tree), so every drift size stays on the repairable side of
/// the separator gate.
fn drifted_by(a: &CsrMatrix, nx: usize, k: usize) -> CsrMatrix {
    let mut coo = CooMatrix::new(a.nrows, a.ncols);
    for r in 0..a.nrows {
        for (t, &c) in a.row_indices(r).iter().enumerate() {
            coo.push(r, c, a.row_data(r)[t]);
        }
    }
    let per_row = nx - 4; // columns 2.. of a row, skipping stencil edges
    assert!(k <= 2 * per_row, "drift size exceeds the safe edit region");
    for e in 0..k {
        let (row, j) = (e / per_row, e % per_row);
        coo.push(row * nx, row * nx + 2 + j, -0.125);
    }
    coo.to_csr()
}

fn main() {
    let mut report = JsonReport::new();
    report.set("bench", json::s("bench_replan"));

    // ── micro lane: repair vs cold re-analysis per drift size ──────────
    // Natural ordering keeps the lane deterministic and ML-free: the
    // donor's frozen permutation is the identity, and the contest is
    // purely symbolic work (full re-analysis) vs incremental repair.
    let (nx, ny) = (40, 40);
    let base = grid2d(nx, ny);
    let cfg = SolverConfig::default();
    let rcfg = RepairConfig::default();
    section(&format!(
        "setup: donor plan (n={}, nnz={})",
        base.nrows,
        base.nnz()
    ));
    let spd = prepare(&base, &cfg);
    let perm = Arc::new(ReorderAlgorithm::Natural.compute(&spd, 0));
    let donor = plan_solve(&base, perm.clone(), &cfg);
    report.set("n", json::num(base.nrows as f64));
    report.set("nnz", json::num(base.nnz() as f64));

    for &drift in &[1usize, 4, 16, 64] {
        section(&format!("drift size {drift}"));
        let drifted = drifted_by(&base, nx, drift);

        // cold re-analysis: what a plan-cache miss costs without the
        // repair tier (symmetrize + reorder + full symbolic analysis)
        let mut b = Bencher::coarse();
        let cold = b
            .bench(&format!("drift{drift}/cold"), || {
                let spd = prepare(&drifted, &cfg);
                let perm = Arc::new(ReorderAlgorithm::Natural.compute(&spd, 0));
                plan_solve(&drifted, perm, &cfg)
            })
            .clone();

        // repair: the whole near-match tier cost — structural diff plus
        // incremental plan repair under the donor's frozen permutation
        let repair = b
            .bench(&format!("drift{drift}/repair"), || {
                let diff = donor.diff_against(&drifted).expect("same order");
                donor
                    .repair(&drifted, &diff, &cfg, &rcfg)
                    .expect("in-budget drift repairs")
            })
            .clone();

        let speedup = cold.min_s / repair.min_s.max(1e-12);
        println!(
            "    cold {:.3} ms -> repair {:.3} ms ({speedup:.1}x)",
            cold.min_s * 1e3,
            repair.min_s * 1e3,
        );
        report.push(json::obj(vec![
            ("drift_edges", json::num(drift as f64)),
            ("cold_s", json::num(cold.min_s)),
            ("repair_s", json::num(repair.min_s)),
            ("speedup", json::num(speedup)),
        ]));
    }

    // ── serving lane: a drifting trace through the full engine ─────────
    section("setup: sweep + train forest backend");
    let train_coll = generate_mini_collection(5, 2);
    let ds = build_dataset(
        &train_coll,
        &ReorderAlgorithm::LABEL_SET,
        &SweepConfig::default(),
    );
    let normalizer = Normalizer::fit(Method::Standard, &ds.features());
    let mut forest = RandomForest::new(
        ForestParams {
            n_estimators: 30,
            ..Default::default()
        },
        5,
    );
    forest.fit(&normalizer.transform(&ds.features()), &ds.labels(), 4);
    let engine = ServingEngine::spawn(
        Backend::Forest { normalizer, forest },
        ServingConfig {
            repair: Some(RepairConfig::default()),
            ..ServingConfig::default()
        },
    )
    .expect("serving engine spawns");

    section("serving: drifting-pattern trace");
    let steps = 12;
    let trace: Vec<CsrMatrix> = (0..=steps).map(|k| drifted_by(&base, nx, k)).collect();
    let t = Timer::start();
    let cold = engine.serve(&trace[0]).expect("base request serves");
    let cold_serve_s = t.elapsed_s();
    let mut repair_serve_s = f64::INFINITY;
    let mut repaired_steps = 0u64;
    for m in &trace[1..] {
        let t = Timer::start();
        let r = engine.serve(m).expect("drift step serves");
        let e = t.elapsed_s();
        if r.repaired {
            repaired_steps += 1;
            repair_serve_s = repair_serve_s.min(e);
        }
    }
    let stats = engine.stats();
    let repair_rate = repaired_steps as f64 / steps as f64;
    if repair_serve_s.is_infinite() {
        repair_serve_s = 0.0; // no step repaired: keep the artifact finite
    }
    println!(
        "    cold serve {:.3} ms | best repaired serve {:.3} ms | {} of {} drift steps repaired \
         ({} fallbacks)",
        cold_serve_s * 1e3,
        repair_serve_s * 1e3,
        repaired_steps,
        steps,
        stats.plans.repair_fallbacks,
    );
    assert!(!cold.plan_hit, "first request must be cold");
    report.set(
        "serving",
        json::obj(vec![
            ("requests", json::num(stats.requests as f64)),
            ("drift_steps", json::num(steps as f64)),
            ("repairs", json::num(stats.plans.repairs as f64)),
            (
                "repair_fallbacks",
                json::num(stats.plans.repair_fallbacks as f64),
            ),
            ("hits", json::num(stats.plans.hits as f64)),
            ("misses", json::num(stats.plans.misses as f64)),
            ("repair_rate", json::num(repair_rate)),
            ("cold_serve_s", json::num(cold_serve_s)),
            ("repair_serve_s", json::num(repair_serve_s)),
        ]),
    );
    engine.shutdown();

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_replan.json".into());
    match report.write(&out) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("\nfailed to write {out}: {e}"),
    }
}

//! Online-learning replay benchmark: a learner-enabled [`ServingEngine`]
//! replays a Zipf trace against ground-truth labels measured up front.
//!
//! Run with `cargo bench --bench bench_online`. Writes
//! `BENCH_online.json` (override with `BENCH_OUT`): a windowed regret
//! curve (regret = oracle-table cost of the arm the engine picked minus
//! the oracle-best cost, charged per request), explored/exploited
//! counts per window, the per-algorithm pick histogram, the learner's
//! own counter block, and three fixed-policy baselines replayed over
//! the *same* trace — always-AMD, the offline model's argmax, and the
//! oracle itself. `ci.sh` schema-gates the artifact via
//! `examples/check_bench` whenever it is present.
//!
//! The headline signal is `regret_improved`: the final window's regret
//! must come in below the first window's (the learner pays its
//! exploration/cold-start tax early and converges onto the measured-
//! cheapest arms).

use smr::collection::generate_mini_collection;
use smr::collection::generators::pattern_population;
use smr::coordinator::service::Backend;
use smr::coordinator::{DrainMode, LearnerConfig, ServingConfig, ServingEngine};
use smr::dataset::{build_dataset, SweepConfig};
use smr::features;
use smr::ml::forest::{ForestParams, RandomForest};
use smr::ml::normalize::{Method, Normalizer};
use smr::ml::online::{arm_index, OnlineConfig, ARMS, N_ARMS};
use smr::ml::Classifier;
use smr::reorder::ReorderAlgorithm;
use smr::solver::{prepare, solve_ordered, SolverConfig};
use smr::util::bench::{section, JsonReport};
use smr::util::cache::CacheConfig;
use smr::util::json;
use smr::util::rng::{Rng, Zipf};
use smr::util::Timer;

const PATTERNS: usize = 18;
const ZIPF_S: f64 = 1.1;
const TRACE_LEN: usize = 600;
const WINDOW: usize = 100;
const REORDER_SEED: u64 = 0xDA7A;

/// Offline predictor trained on a *small* sweep on purpose: its argmax
/// is good but imperfect on the replay population, which is exactly the
/// regime where the online loop has something to learn.
fn trained_model() -> (Normalizer, RandomForest) {
    let coll = generate_mini_collection(3, 1);
    let ds = build_dataset(&coll, &ReorderAlgorithm::LABEL_SET, &SweepConfig::default());
    let normalizer = Normalizer::fit(Method::Standard, &ds.features());
    let mut forest = RandomForest::new(
        ForestParams {
            n_estimators: 20,
            ..Default::default()
        },
        9,
    );
    forest.fit(&normalizer.transform(&ds.features()), &ds.labels(), 4);
    (normalizer, forest)
}

fn main() {
    section("setup: sweep + train offline forest");
    let (normalizer, forest) = trained_model();
    let backend = Backend::Forest {
        normalizer: normalizer.clone(),
        forest: forest.clone(),
    };

    section(&format!(
        "oracle: measure all {N_ARMS} arms on {PATTERNS} patterns"
    ));
    let pop = pattern_population(PATTERNS, 0xD1CE);
    let solver_cfg = SolverConfig::default();
    // table[p][a] = measured reorder+analyze+factor+solve cost of arm a
    // on pattern p; failures normalized to 2x the worst finite cost.
    let mut table = vec![[0.0f64; N_ARMS]; PATTERNS];
    for (p, m) in pop.iter().enumerate() {
        let spd = prepare(m, &solver_cfg);
        let mut worst = 0.0f64;
        for (ai, arm) in ARMS.iter().enumerate() {
            let t = Timer::start();
            let perm = arm.compute(&spd, REORDER_SEED);
            let reorder_s = t.elapsed_s();
            let cost = match solve_ordered(&spd, &perm, &solver_cfg) {
                Ok(rep) => reorder_s + rep.total_s(),
                Err(_) => f64::INFINITY,
            };
            table[p][ai] = cost;
            if cost.is_finite() {
                worst = worst.max(cost);
            }
        }
        for c in table[p].iter_mut() {
            if !c.is_finite() {
                *c = 2.0 * worst.max(1e-6);
            }
        }
    }
    let best: Vec<f64> = table
        .iter()
        .map(|row| row.iter().cloned().fold(f64::INFINITY, f64::min))
        .collect();
    // Fixed-policy arm choices for the baselines.
    let amd_ix = arm_index(ReorderAlgorithm::Amd).expect("AMD is an arm");
    let offline_ix: Vec<usize> = pop
        .iter()
        .map(|m| {
            let f = features::extract(m);
            let label = forest.predict(&normalizer.transform_row(&f));
            arm_index(ReorderAlgorithm::from_label(label)).expect("labels are arms")
        })
        .collect();

    section(&format!(
        "replay: Zipf(s={ZIPF_S}) trace of {TRACE_LEN} through the learner-enabled engine"
    ));
    let engine = ServingEngine::spawn(
        backend,
        ServingConfig {
            plan_cache: CacheConfig {
                capacity: 256,
                shards: 8,
            },
            reorder_seed: REORDER_SEED,
            learner: Some(LearnerConfig {
                online: OnlineConfig {
                    epsilon: 0.15,
                    ..OnlineConfig::default()
                },
                queue_capacity: 4096,
                drain: DrainMode::Inband { every: 16 },
            }),
            ..ServingConfig::default()
        },
    )
    .expect("engine spawns");

    let zipf = Zipf::new(PATTERNS, ZIPF_S);
    let mut rng = Rng::new(0x7AFF);
    let trace: Vec<usize> = (0..TRACE_LEN).map(|_| zipf.sample(&mut rng)).collect();

    let n_windows = TRACE_LEN.div_ceil(WINDOW);
    let mut win_regret = vec![0.0f64; n_windows];
    let mut win_requests = vec![0u64; n_windows];
    let mut win_explored = vec![0u64; n_windows];
    let mut picks = [0u64; N_ARMS];
    let (mut learner_regret, mut amd_regret, mut model_regret, mut oracle_total) =
        (0.0f64, 0.0, 0.0, 0.0);

    for (t, &p) in trace.iter().enumerate() {
        let r = engine.serve(&pop[p]).expect("replay serve");
        let ai = arm_index(r.algorithm).expect("served arm is in ARMS");
        let regret = table[p][ai] - best[p];
        let w = t / WINDOW;
        win_regret[w] += regret;
        win_requests[w] += 1;
        win_explored[w] += r.explored as u64;
        picks[ai] += 1;
        learner_regret += regret;
        amd_regret += table[p][amd_ix] - best[p];
        model_regret += table[p][offline_ix[p]] - best[p];
        oracle_total += best[p];
        engine.learner().expect("learner enabled").record_regret(regret);
    }
    engine.learner().expect("learner enabled").drain_now();

    let mut report = JsonReport::new();
    report.set("bench", json::s("bench_online"));
    report.set("patterns", json::num(PATTERNS as f64));
    report.set("zipf_s", json::num(ZIPF_S));
    report.set("trace_len", json::num(TRACE_LEN as f64));
    report.set("window", json::num(WINDOW as f64));

    for w in 0..n_windows {
        let reqs = win_requests[w].max(1) as f64;
        println!(
            "    window {w}: regret {:.4}s over {} reqs ({:.5}s/req) | explored {}",
            win_regret[w],
            win_requests[w],
            win_regret[w] / reqs,
            win_explored[w],
        );
        report.push(json::obj(vec![
            ("name", json::s(&format!("window_{w}"))),
            ("window", json::num(w as f64)),
            ("requests", json::num(win_requests[w] as f64)),
            ("regret_s", json::num(win_regret[w])),
            ("regret_per_req_s", json::num(win_regret[w] / reqs)),
            ("explored", json::num(win_explored[w] as f64)),
            (
                "exploited",
                json::num((win_requests[w] - win_explored[w]) as f64),
            ),
        ]));
    }

    let s = engine.stats();
    report.set(
        "picks",
        json::arr(ARMS.iter().enumerate().map(|(ai, arm)| {
            json::obj(vec![
                ("algorithm", json::s(arm.name())),
                ("picked", json::num(picks[ai] as f64)),
            ])
        })),
    );
    report.set(
        "baselines",
        json::obj(vec![
            ("oracle_total_s", json::num(oracle_total)),
            ("amd_regret_s", json::num(amd_regret)),
            ("model_regret_s", json::num(model_regret)),
            ("learner_regret_s", json::num(learner_regret)),
        ]),
    );
    report.set(
        "learner",
        json::obj(vec![
            ("decisions", json::num(s.learner.decisions as f64)),
            ("explored", json::num(s.learner.explored as f64)),
            ("observations", json::num(s.learner.observations as f64)),
            ("updates", json::num(s.learner.updates as f64)),
            ("dropped", json::num(s.learner.dropped as f64)),
            ("regret_s", json::num(s.learner.regret_s)),
        ]),
    );
    let first = win_regret[0];
    let last = win_regret[n_windows - 1];
    report.set("first_window_regret_s", json::num(first));
    report.set("final_window_regret_s", json::num(last));
    report.set("regret_improved", json::b(last < first));

    println!(
        "\n    regret: learner {learner_regret:.4}s | always-AMD {amd_regret:.4}s | \
         offline model {model_regret:.4}s | oracle total {oracle_total:.4}s"
    );
    println!(
        "    first window {first:.4}s -> final window {last:.4}s (improved: {})",
        last < first
    );

    engine.shutdown();

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_online.json".into());
    match report.write(&out) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("\nfailed to write {out}: {e}"),
    }
}

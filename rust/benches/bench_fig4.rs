//! Bench for paper Fig. 4: training cost of each classifier family on a
//! labeled dataset (the figure itself is accuracy; this bench tracks the
//! cost of producing it). Run with `cargo bench --bench bench_fig4`.

use smr::collection::generate_mini_collection;
use smr::dataset::{build_dataset, SweepConfig};
use smr::ml::forest::{ForestParams, RandomForest};
use smr::ml::knn::{Knn, KnnParams};
use smr::ml::logreg::{LogRegParams, LogisticRegression};
use smr::ml::naive_bayes::GaussianNB;
use smr::ml::normalize::{Method, Normalizer};
use smr::ml::svm::{LinearSvm, SvmParams};
use smr::ml::tree::{DecisionTree, TreeParams};
use smr::ml::Classifier;
use smr::reorder::ReorderAlgorithm;
use smr::util::bench::{section, Bencher};

fn main() {
    let coll = generate_mini_collection(3, 6);
    let ds = build_dataset(&coll, &ReorderAlgorithm::LABEL_SET, &SweepConfig::default());
    let x_raw = ds.features();
    let y = ds.labels();
    let norm = Normalizer::fit(Method::Standard, &x_raw);
    let x = norm.transform(&x_raw);
    section(&format!("Fig. 4 model training ({} rows)", x.len()));

    let mut b = Bencher::new();
    b.bench("fit RandomForest(100)", || {
        let mut m = RandomForest::new(ForestParams::default(), 1);
        m.fit(&x, &y, 4);
        m
    });
    b.bench("fit DecisionTree", || {
        let mut m = DecisionTree::new(TreeParams::default(), 1);
        m.fit(&x, &y, 4);
        m
    });
    b.bench("fit LogisticRegression", || {
        let mut m = LogisticRegression::new(LogRegParams::default());
        m.fit(&x, &y, 4);
        m
    });
    b.bench("fit GaussianNB", || {
        let mut m = GaussianNB::new();
        m.fit(&x, &y, 4);
        m
    });
    b.bench("fit LinearSvm", || {
        let mut m = LinearSvm::new(SvmParams::default());
        m.fit(&x, &y, 4);
        m
    });
    b.bench("fit KNN", || {
        let mut m = Knn::new(KnnParams::default());
        m.fit(&x, &y, 4);
        m
    });

    section("inference (single row)");
    let mut forest = RandomForest::new(ForestParams::default(), 1);
    forest.fit(&x, &y, 4);
    let mut b = Bencher::new();
    b.bench("RandomForest predict", || forest.predict(&x[0]));
}

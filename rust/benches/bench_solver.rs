//! Direct-solver phase benchmarks: analyze / factorize / solve, plus the
//! ordering-sensitivity of factor time (the effect the whole paper is
//! built on). Run with `cargo bench --bench bench_solver`.

use smr::collection::generators as g;
use smr::reorder::ReorderAlgorithm;
use smr::solver::{self, SolverConfig};
use smr::util::bench::{section, Bencher};

fn main() {
    let cfg = SolverConfig::default();
    let cases = vec![
        ("grid2d_40x40", g::grid2d(40, 40)),
        ("grid2d_64x64", g::grid2d(64, 64)),
        ("grid3d_12", g::grid3d(12, 12, 12)),
    ];
    for (name, raw) in &cases {
        let a = solver::prepare(raw, &cfg);
        let perm = ReorderAlgorithm::Amd.compute(&a, 1);
        let pa = perm.apply(&a);
        let sym = solver::analyze(&pa);
        section(&format!(
            "solver: {name} (n={}, nnz={}, fill={})",
            a.nrows,
            a.nnz(),
            sym.cost.fill
        ));
        let mut b = Bencher::new();
        b.bench(&format!("{name}/analyze"), || solver::analyze(&pa));
        let f = solver::factorize(&pa, &sym).unwrap();
        b.bench(&format!("{name}/factorize"), || {
            solver::factorize(&pa, &sym).unwrap()
        });
        let rhs = vec![1.0; a.nrows];
        b.bench(&format!("{name}/solve"), || f.solve(&rhs));
    }

    section("ordering sensitivity (factor time, grid2d 56x56)");
    let a = solver::prepare(&g::grid2d(56, 56), &cfg);
    let mut b = Bencher::new();
    for alg in [
        ReorderAlgorithm::Natural,
        ReorderAlgorithm::Rcm,
        ReorderAlgorithm::Amd,
        ReorderAlgorithm::Nd,
        ReorderAlgorithm::Scotch,
    ] {
        let perm = alg.compute(&a, 1);
        let pa = perm.apply(&a);
        let sym = solver::analyze(&pa);
        b.bench(
            &format!("factor under {alg} (fill {})", sym.cost.fill),
            || solver::factorize(&pa, &sym).unwrap(),
        );
    }
}

//! Direct-solver benchmarks: scalar up-looking vs supernodal
//! multifrontal (sequential and subtree-parallel) on the generated
//! suite, plus the ordering-sensitivity of factor time (the effect the
//! whole paper is built on).
//!
//! Run with `cargo bench --bench bench_solver`. Besides the console
//! report it writes a machine-readable `BENCH_solver.json` (override the
//! path with `BENCH_OUT`) so future PRs can diff the perf trajectory:
//! one record per (matrix, factor mode) with wall times, flop counts,
//! achieved flop rates, the plan's `peak_front_bytes` (the per-worker
//! arena sizing) and the lane's observed front `allocs` (arena growth
//! events during the timed loop), plus per-matrix supernodal speedups
//! and three numeric-replay lanes:
//!
//! * `planned_numeric` — frozen `SymbolicFactorization`, value refresh +
//!   factorize only, measured **cold** (its alloc count includes the
//!   one-time arena sizing — the price the first request per plan pays);
//! * `arena_numeric`  — the same sequential replay after warmup: the
//!   steady-state serving cost, expected `allocs == 0`;
//! * `pipelined`      — the DAG-pipelined replay (subtree parallelism +
//!   pipelined top of the tree) after warmup, also `allocs == 0`.
//!
//! Two lane families ride along per matrix:
//!
//! * `batched_warm` (k ∈ {1, 2, 4, 8}) — k same-pattern, value-distinct
//!   requests factored by ONE k-wide traversal
//!   (`factorize_with_plan_batch` on the pipelined plan). Each record
//!   carries `batch_k`, `throughput_per_s` (requests per second) and
//!   `per_request_s` (batch wall time / k); `speedup_vs_single` is the
//!   per-request amortization against the single-request `pipelined`
//!   lane — the number the batching tentpole claims ≥ 3× at k = 8.
//! * `core_scaling_w{N}` — the pipelined replay pinned to explicit
//!   worker counts (1, 2, 4, …, default), exposing how far the DAG
//!   schedule scales before the top of the tree serializes.

use smr::collection::generators as g;
use smr::reorder::ReorderAlgorithm;
use smr::solver::{self, arena, FactorConfig, FactorMode, SolverConfig};
use smr::sparse::CsrMatrix;
use smr::util::bench::{section, Bencher, JsonReport};
use smr::util::json;
use smr::util::pool;
use smr::util::rng::Rng;

fn mode_cfg(mode: FactorMode) -> FactorConfig {
    FactorConfig {
        mode,
        parallel_flop_min: 0.0,
        ..FactorConfig::default()
    }
}

fn mode_name(mode: FactorMode) -> &'static str {
    match mode {
        FactorMode::Scalar => "scalar",
        FactorMode::Supernodal => "supernodal",
        FactorMode::SupernodalParallel => "supernodal_parallel",
    }
}

fn main() {
    let cfg = SolverConfig::default();
    let mut rng = Rng::new(0xBE7C);
    // the acceptance suite: 2D/3D grid Laplacians and random SPD, n >= 10k,
    // plus two smaller smoke cases for quick eyeballing
    let cases = vec![
        ("grid2d_64x64", "grid2d", g::grid2d(64, 64)),
        ("grid3d_12", "grid3d", g::grid3d(12, 12, 12)),
        ("grid2d_100x100", "grid2d", g::grid2d(100, 100)),
        ("grid3d_22", "grid3d", g::grid3d(22, 22, 22)),
        // avg degree kept low: ER-random graphs have no good separators,
        // so denser ones blow the scalar baseline's bench time out
        ("random_spd_10k", "random_spd", g::random_sym(10_000, 2.5, &mut rng)),
    ];
    let modes = [
        FactorMode::Scalar,
        FactorMode::Supernodal,
        FactorMode::SupernodalParallel,
    ];

    let mut report = JsonReport::new();
    report.set("bench", json::s("bench_solver"));
    report.set("workers", json::num(pool::default_workers() as f64));

    for (name, family, raw) in &cases {
        let a = solver::prepare(raw, &cfg);
        let perm = ReorderAlgorithm::Amd.compute(&a, 1);
        let pa = perm.apply(&a);
        let sym = solver::analyze(&pa);
        section(&format!(
            "solver: {name} (n={}, nnz={}, fill={}, flops={:.3e})",
            a.nrows,
            a.nnz(),
            sym.cost.fill,
            sym.cost.flops
        ));
        let mut b = Bencher::coarse();
        let mut scalar_min = f64::NAN;
        for mode in modes {
            let fcfg = mode_cfg(mode);
            let an = solver::analyze_with(&pa, &fcfg);
            let peak_front_bytes = an.plan.as_ref().map_or(0, |p| p.peak_front_bytes());
            let f = solver::factorize_with(&pa, &an, &fcfg).unwrap();
            assert_eq!(f.fill(), sym.cost.fill, "fill must not depend on mode");
            let label = format!("{name}/factorize/{}", mode_name(mode));
            let g0 = arena::grow_events();
            let m = b
                .bench(&label, || {
                    solver::factorize_with(&pa, &an, &fcfg).unwrap()
                })
                .clone();
            let allocs = arena::grow_events() - g0;
            if mode == FactorMode::Scalar {
                scalar_min = m.min_s;
            }
            report.push(json::obj(vec![
                ("name", json::s(&label)),
                ("family", json::s(family)),
                ("n", json::num(a.nrows as f64)),
                ("nnz", json::num(a.nnz() as f64)),
                ("fill", json::num(sym.cost.fill as f64)),
                ("mode", json::s(mode_name(mode))),
                ("wall_s", json::num(m.min_s)),
                ("mean_s", json::num(m.mean_s)),
                ("flops", json::num(f.flops)),
                ("flop_rate", json::num(f.flops / m.min_s.max(1e-12))),
                ("speedup_vs_scalar", json::num(scalar_min / m.min_s.max(1e-12))),
                ("peak_front_bytes", json::num(peak_front_bytes as f64)),
                ("allocs", json::num(allocs as f64)),
            ]));
        }
        // numeric-only replay lanes: the symbolic factorization is
        // frozen once (what the serving plan cache holds), then each
        // iteration refreshes values + factorizes — the warm-request
        // cost, with the symmetrize/permute/analyze phases gone.
        // `planned_numeric` measures from cold (arena sizing included);
        // `arena_numeric` and `pipelined` warm up first, so their alloc
        // column is the steady-state claim: zero front allocations.
        let plan_cfg = SolverConfig {
            factor: mode_cfg(FactorMode::Supernodal),
            ..cfg
        };
        let plan = solver::plan_solve(
            raw,
            std::sync::Arc::new(perm.clone()),
            &plan_cfg,
        );
        let pipe_cfg = SolverConfig {
            factor: mode_cfg(FactorMode::SupernodalParallel),
            ..cfg
        };
        let pipe_plan = solver::plan_solve(
            raw,
            std::sync::Arc::new(perm.clone()),
            &pipe_cfg,
        );
        let mut ws = solver::NumericWorkspace::new();
        let mut push_plan_lane =
            |b: &mut Bencher,
             lane: &str,
             plan: &solver::SymbolicFactorization,
             ws: &mut solver::NumericWorkspace,
             warmups: usize| {
                for _ in 0..warmups {
                    solver::factorize_with_plan(raw, plan, ws).unwrap();
                }
                let label = format!("{name}/factorize/{lane}");
                let g0 = arena::grow_events();
                let m = b
                    .bench(&label, || {
                        solver::factorize_with_plan(raw, plan, ws).unwrap()
                    })
                    .clone();
                let allocs = arena::grow_events() - g0;
                report.push(json::obj(vec![
                    ("name", json::s(&label)),
                    ("family", json::s(family)),
                    ("n", json::num(a.nrows as f64)),
                    ("nnz", json::num(a.nnz() as f64)),
                    ("fill", json::num(sym.cost.fill as f64)),
                    ("mode", json::s(lane)),
                    ("wall_s", json::num(m.min_s)),
                    ("mean_s", json::num(m.mean_s)),
                    (
                        "speedup_vs_scalar",
                        json::num(scalar_min / m.min_s.max(1e-12)),
                    ),
                    ("peak_front_bytes", json::num(plan.peak_front_bytes() as f64)),
                    ("allocs", json::num(allocs as f64)),
                ]));
                m.min_s
            };
        // cold lane on a FRESH thread: its thread-pinned serial arena
        // has never seen any plan, so the alloc column genuinely counts
        // the one-time sizing (the mode lanes above already warmed the
        // main thread's arena for this matrix)
        std::thread::scope(|sc| {
            sc.spawn(|| push_plan_lane(&mut b, "planned_numeric", &plan, &mut ws, 0))
                .join()
                .expect("cold planned_numeric lane");
        });
        push_plan_lane(&mut b, "arena_numeric", &plan, &mut ws, 1);
        let pipelined_warm = push_plan_lane(&mut b, "pipelined", &pipe_plan, &mut ws, 3);

        // core-count scaling: the pipelined numeric replay at explicit
        // worker counts; the mode name encodes the count
        let max_w = pool::default_workers().max(1);
        let mut w = 1usize;
        loop {
            let scfg = SolverConfig {
                factor: FactorConfig {
                    workers: w,
                    ..mode_cfg(FactorMode::SupernodalParallel)
                },
                ..cfg
            };
            let splan = solver::plan_solve(raw, std::sync::Arc::new(perm.clone()), &scfg);
            push_plan_lane(&mut b, &format!("core_scaling_w{w}"), &splan, &mut ws, 2);
            if w >= max_w {
                break;
            }
            w = (w * 2).min(max_w);
        }

        // batched warm lanes: k same-pattern, value-distinct requests
        // through one k-wide traversal of the pipelined plan. Warmed up
        // first so the k-wide arena sizing (one counted growth per new
        // (plan, k)) stays out of the timed window — steady-state
        // batches are allocation-free for fronts like the single path.
        let variants: Vec<CsrMatrix> = (0..8)
            .map(|l| {
                let mut m = raw.clone();
                for v in m.data.iter_mut() {
                    *v *= 1.0 + 0.0625 * l as f64;
                }
                m
            })
            .collect();
        let mut wss: Vec<solver::NumericWorkspace> =
            (0..8).map(|_| solver::NumericWorkspace::new()).collect();
        for k in [1usize, 2, 4, 8] {
            let mats: Vec<&CsrMatrix> = variants[..k].iter().collect();
            for r in solver::factorize_with_plan_batch(&mats, &pipe_plan, &mut wss[..k]) {
                r.unwrap();
            }
            let label = format!("{name}/factorize/batched_warm_k{k}");
            let g0 = arena::grow_events();
            let m = b
                .bench(&label, || {
                    for r in
                        solver::factorize_with_plan_batch(&mats, &pipe_plan, &mut wss[..k])
                    {
                        r.unwrap();
                    }
                })
                .clone();
            let allocs = arena::grow_events() - g0;
            let per_request_s = m.min_s / k as f64;
            report.push(json::obj(vec![
                ("name", json::s(&label)),
                ("family", json::s(family)),
                ("n", json::num(a.nrows as f64)),
                ("nnz", json::num(a.nnz() as f64)),
                ("fill", json::num(sym.cost.fill as f64)),
                ("mode", json::s("batched_warm")),
                ("batch_k", json::num(k as f64)),
                ("wall_s", json::num(m.min_s)),
                ("mean_s", json::num(m.mean_s)),
                ("per_request_s", json::num(per_request_s)),
                ("throughput_per_s", json::num(k as f64 / m.min_s.max(1e-12))),
                (
                    "speedup_vs_single",
                    json::num(pipelined_warm / per_request_s.max(1e-12)),
                ),
                (
                    "peak_front_bytes",
                    json::num((pipe_plan.peak_front_bytes() * k) as f64),
                ),
                ("allocs", json::num(allocs as f64)),
            ]));
        }

        // solve cost rides along (shared by every mode)
        let an = solver::analyze_with(&pa, &mode_cfg(FactorMode::Supernodal));
        let f = solver::factorize_with(&pa, &an, &mode_cfg(FactorMode::Supernodal))
            .unwrap();
        let rhs = vec![1.0; a.nrows];
        b.bench(&format!("{name}/solve"), || f.solve(&rhs));
    }

    section("ordering sensitivity (factor time, grid2d 56x56, default path)");
    let a = solver::prepare(&g::grid2d(56, 56), &cfg);
    let mut b = Bencher::new();
    for alg in [
        ReorderAlgorithm::Natural,
        ReorderAlgorithm::Rcm,
        ReorderAlgorithm::Amd,
        ReorderAlgorithm::Nd,
        ReorderAlgorithm::Scotch,
    ] {
        let perm = alg.compute(&a, 1);
        let pa = perm.apply(&a);
        let fcfg = FactorConfig::default();
        let an = solver::analyze_with(&pa, &fcfg);
        b.bench(
            &format!("factor under {alg} (fill {})", an.cost.fill),
            || solver::factorize_with(&pa, &an, &fcfg).unwrap(),
        );
    }

    // solver-wide front-arena counters (the zero-alloc trajectory)
    let fr = arena::stats();
    println!(
        "\nfront arenas: {} checkouts / {} creates / {} reuses | boundary bufs: {} checkouts | {} grow events",
        fr.arenas.checkouts, fr.arenas.creates, fr.arenas.reuses, fr.boundary.checkouts, fr.grows
    );
    report.set(
        "fronts",
        json::obj(vec![
            ("checkouts", json::num(fr.arenas.checkouts as f64)),
            ("creates", json::num(fr.arenas.creates as f64)),
            ("reuses", json::num(fr.arenas.reuses as f64)),
            ("boundary_checkouts", json::num(fr.boundary.checkouts as f64)),
            ("grows", json::num(fr.grows as f64)),
        ]),
    );

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_solver.json".into());
    match report.write(&out) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("\nfailed to write {out}: {e}"),
    }
}

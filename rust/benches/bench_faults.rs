//! Fault-injection benchmark for the serving engine: the identical
//! Zipf replay run fault-free and under 1% / 5% / 10% injected numeric
//! failures, measuring what graceful degradation actually costs.
//!
//! Run with `cargo bench --bench bench_faults`. Writes
//! `BENCH_faults.json` (override with `BENCH_OUT`): one record per
//! fault-rate lane with goodput, fallback rate, the exact fault ledger
//! (injected / fired / fallbacks / quarantine trips and skips), and
//! p50/p99/p999 end-to-end latency — the tail tells how much a faulted
//! request's extra chain attempt costs the whole distribution. `ci.sh`
//! schema-gates the artifact via `examples/check_bench` whenever it is
//! present.
//!
//! Requests are served sequentially so the engine-wide request index is
//! the trace index — the fault schedule is exact and the run is fully
//! reproducible (seeded population, trace, and Bernoulli fault draw).

use std::sync::Arc;
use std::time::Duration;

use smr::collection::generate_mini_collection;
use smr::collection::generators::pattern_population;
use smr::coordinator::service::Backend;
use smr::coordinator::{ServingConfig, ServingEngine};
use smr::dataset::{build_dataset, SweepConfig};
use smr::ml::forest::{ForestParams, RandomForest};
use smr::ml::normalize::{Method, Normalizer};
use smr::ml::Classifier;
use smr::reorder::ReorderAlgorithm;
use smr::solver::QuarantineConfig;
use smr::util::bench::{section, JsonReport};
use smr::util::deadline::Stage;
use smr::util::faults::{Fault, FaultPlan};
use smr::util::json;
use smr::util::rng::{Rng, Zipf};
use smr::util::Timer;

const PATTERNS: usize = 24;
const ZIPF_S: f64 = 1.1;
const TRACE_LEN: usize = 400;

fn trained_backend() -> Backend {
    let train_coll = generate_mini_collection(5, 2);
    let ds = build_dataset(
        &train_coll,
        &ReorderAlgorithm::LABEL_SET,
        &SweepConfig::default(),
    );
    let normalizer = Normalizer::fit(Method::Standard, &ds.features());
    let mut forest = RandomForest::new(
        ForestParams {
            n_estimators: 30,
            ..Default::default()
        },
        5,
    );
    forest.fit(&normalizer.transform(&ds.features()), &ds.labels(), 4);
    Backend::Forest { normalizer, forest }
}

struct LaneResult {
    served: u64,
    errors: u64,
    elapsed_s: f64,
    stats: smr::coordinator::ServingStats,
}

/// Replay the trace sequentially against a fresh engine carrying the
/// given fault schedule.
fn run_lane(
    backend: &Backend,
    trace: &[usize],
    pop: &[smr::sparse::CsrMatrix],
    faults: Option<Arc<FaultPlan>>,
) -> LaneResult {
    let engine = ServingEngine::spawn(
        backend.clone(),
        ServingConfig {
            // defaults except a trip-able quarantine with a TTL longer
            // than the run, so tombstones stay visible in the counters
            quarantine: QuarantineConfig {
                strikes: 3,
                ttl: Duration::from_secs(600),
            },
            faults,
            ..ServingConfig::default()
        },
    )
    .expect("engine spawns");
    let mut served = 0u64;
    let mut errors = 0u64;
    let t = Timer::start();
    for &p in trace {
        match engine.serve(&pop[p]) {
            Ok(_) => served += 1,
            Err(_) => errors += 1,
        }
    }
    let elapsed_s = t.elapsed_s();
    let stats = engine.stats();
    engine.shutdown();
    LaneResult {
        served,
        errors,
        elapsed_s,
        stats,
    }
}

fn lane_record(name: &str, rate: f64, injected: usize, lane: &LaneResult) -> json::Json {
    let s = &lane.stats;
    let e2e = &s.latency.e2e;
    println!(
        "    {name}: goodput {:.1} req/s | errors {} | injected {injected} fired {} \
         fallbacks {} | quarantined {} skips {} | p50 {:.3} ms p99 {:.3} ms p999 {:.3} ms",
        lane.served as f64 / lane.elapsed_s.max(1e-12),
        lane.errors,
        s.faults_fired,
        s.fallbacks,
        s.plans.quarantined,
        s.plans.quarantine_skips,
        e2e.p50() * 1e3,
        e2e.p99() * 1e3,
        e2e.p999() * 1e3,
    );
    json::obj(vec![
        ("name", json::s(name)),
        ("fault_rate", json::num(rate)),
        ("requests", json::num(s.requests as f64)),
        ("served", json::num(lane.served as f64)),
        ("errors", json::num(lane.errors as f64)),
        ("injected", json::num(injected as f64)),
        ("faults_fired", json::num(s.faults_fired as f64)),
        ("fallbacks", json::num(s.fallbacks as f64)),
        ("quarantined", json::num(s.plans.quarantined as f64)),
        (
            "quarantine_skips",
            json::num(s.plans.quarantine_skips as f64),
        ),
        (
            "deadline_expired",
            json::num(s.deadline_expired_total() as f64),
        ),
        ("elapsed_s", json::num(lane.elapsed_s)),
        (
            "goodput_per_s",
            json::num(lane.served as f64 / lane.elapsed_s.max(1e-12)),
        ),
        (
            "fallback_rate",
            json::num(s.fallbacks as f64 / (s.requests as f64).max(1.0)),
        ),
        ("p50_s", json::num(e2e.p50())),
        ("p99_s", json::num(e2e.p99())),
        ("p999_s", json::num(e2e.p999())),
        ("mean_s", json::num(e2e.mean_s())),
    ])
}

fn main() {
    section("setup: sweep + train forest backend");
    let backend = trained_backend();

    section(&format!(
        "setup: {PATTERNS}-pattern population, Zipf(s={ZIPF_S}) trace of {TRACE_LEN}"
    ));
    let pop = pattern_population(PATTERNS, 0xD1CE);
    let zipf = Zipf::new(PATTERNS, ZIPF_S);
    let mut rng = Rng::new(0x7AFF);
    let trace: Vec<usize> = (0..TRACE_LEN).map(|_| zipf.sample(&mut rng)).collect();

    let mut report = JsonReport::new();
    report.set("bench", json::s("bench_faults"));
    report.set("patterns", json::num(PATTERNS as f64));
    report.set("zipf_s", json::num(ZIPF_S));
    report.set("trace_len", json::num(TRACE_LEN as f64));

    section("replay: fault-free baseline");
    let baseline = run_lane(&backend, &trace, &pop, None);
    report.set("baseline_p999_s", json::num(baseline.stats.latency.e2e.p999()));
    report.set(
        "baseline_goodput_per_s",
        json::num(baseline.served as f64 / baseline.elapsed_s.max(1e-12)),
    );
    report.push(lane_record("faults_0pct", 0.0, 0, &baseline));

    for (tag, rate) in [("faults_1pct", 0.01), ("faults_5pct", 0.05), ("faults_10pct", 0.10)] {
        section(&format!("replay: {:.0}% injected numeric failures", rate * 100.0));
        let plan = Arc::new(FaultPlan::bernoulli(
            0xFA_17,
            TRACE_LEN as u64,
            rate,
            Stage::Numeric,
            Fault::FailNumeric,
        ));
        let injected = plan.len();
        let lane = run_lane(&backend, &trace, &pop, Some(plan));
        // graceful degradation is the product: nothing errors out, and
        // the ledger closes — every fired fault is exactly one fallback
        assert_eq!(lane.errors, 0, "{tag}: a faulted request errored out");
        assert_eq!(
            lane.stats.fallbacks, lane.stats.faults_fired,
            "{tag}: fired faults and fallback hops must reconcile"
        );
        assert!(
            lane.stats.faults_fired <= injected as u64,
            "{tag}: fired more faults than scheduled"
        );
        report.push(lane_record(tag, rate, injected, &lane));
    }

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_faults.json".into());
    match report.write(&out) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("\nfailed to write {out}: {e}"),
    }
}

//! END-TO-END VALIDATION DRIVER (EXPERIMENTS.md §End-to-end).
//!
//! Proves all three layers compose on a real workload:
//!   Layer 1/2 — the Pallas-kernel MLP artifacts are trained via the AOT
//!               PJRT train-step on a freshly swept dataset (loss logged);
//!   Layer 3  — the trained model serves predictions inside the
//!               coordinator, driving reorder+factorize+solve on a
//!               held-out workload; we report the paper's headline
//!               metric (total solve time: always-AMD vs predicted vs
//!               ideal, plus speedup).
//!
//! Requires artifacts (`make artifacts`); falls back to the Random Forest
//! backend when they are absent so the driver always runs.
//!
//! Run: cargo run --release --example end_to_end

use std::path::Path;

use smr::collection::generate_mini_collection;
use smr::coordinator::{train_forest, train_mlp};
use smr::dataset::{build_dataset, SweepConfig};
use smr::ml::normalize::Method;
use smr::model::TrainConfig;
use smr::reorder::ReorderAlgorithm;
use smr::runtime::{Manifest, Runtime};
use smr::util::Timer;

fn main() -> anyhow::Result<()> {
    // ---- workload: a 72-matrix collection, swept and labeled ----------
    let collection = generate_mini_collection(99, 12);
    println!("[1/4] sweeping {} matrices ...", collection.len());
    let t = Timer::start();
    let dataset = build_dataset(
        &collection,
        &ReorderAlgorithm::LABEL_SET,
        &SweepConfig::default(),
    );
    println!(
        "      swept in {:.1}s; labels [AMD,SCOTCH,ND,RCM] = {:?}",
        t.elapsed_s(),
        dataset.label_distribution()
    );
    let (train_idx, test_idx) = dataset.split(0.8, 99);

    // ---- train the MLP through the AOT artifacts (L1+L2) --------------
    let artifacts = Path::new("artifacts");
    let use_mlp = artifacts.join("manifest.json").exists();
    let mut mlp_loss_head = Vec::new();
    let mut mlp_loss_tail = Vec::new();

    let predictions: Vec<usize> = if use_mlp {
        println!("[2/4] training AOT MLP via PJRT train-step ...");
        let runtime = Runtime::cpu()?;
        let manifest = Manifest::load(artifacts)?;
        let t = Timer::start();
        let trained = train_mlp(
            &runtime,
            &manifest,
            &dataset,
            &train_idx,
            &TrainConfig {
                epochs: 100,
                ..Default::default()
            },
        )?;
        mlp_loss_head = trained.losses.iter().take(5).copied().collect();
        mlp_loss_tail = trained
            .losses
            .iter()
            .rev()
            .take(5)
            .rev()
            .copied()
            .collect();
        println!(
            "      arch {} | val acc {:.2} | {:.1}s | loss {:?} -> {:?}",
            trained.arch,
            trained.val_accuracy,
            t.elapsed_s(),
            mlp_loss_head,
            mlp_loss_tail,
        );
        let driver = smr::model::MlpDriver::new(&runtime, &manifest);
        let all_x = dataset.features();
        let xs: Vec<Vec<f64>> = test_idx.iter().map(|&i| all_x[i].clone()).collect();
        driver.predict(&trained.model, &xs)?
    } else {
        println!("[2/4] artifacts missing -> Random Forest backend");
        let tf = train_forest(&dataset, &train_idx, Method::Standard, 99);
        let all_x = dataset.features();
        test_idx
            .iter()
            .map(|&i| {
                smr::ml::Classifier::predict(
                    &tf.forest,
                    &tf.normalizer.transform_row(&all_x[i]),
                )
            })
            .collect()
    };

    // ---- serve the held-out workload through the coordinator ----------
    println!("[3/4] replaying the held-out workload ...");
    let mut amd_s = 0.0;
    let mut pred_s = 0.0;
    let mut ideal_s = 0.0;
    let mut correct = 0usize;
    for (k, &i) in test_idx.iter().enumerate() {
        let rec = &dataset.records[i];
        let pred_alg = ReorderAlgorithm::from_label(predictions[k]);
        amd_s += rec.time_of(ReorderAlgorithm::Amd).unwrap();
        pred_s += rec.time_of(pred_alg).unwrap();
        ideal_s += rec.best().total_s;
        if Some(rec.label) == pred_alg.label_index() {
            correct += 1;
        }
    }

    // ---- headline metric ----------------------------------------------
    println!("[4/4] headline (paper Table 6 shape):");
    println!("      always-AMD total   : {amd_s:.4}s");
    println!(
        "      predicted total    : {pred_s:.4}s ({:+.1}% vs AMD; paper -55.4%)",
        100.0 * (pred_s / amd_s - 1.0)
    );
    println!(
        "      ideal total        : {ideal_s:.4}s (predicted is {:+.1}% above; paper +19.9%)",
        100.0 * (pred_s / ideal_s - 1.0)
    );
    println!(
        "      test accuracy      : {}/{} = {:.1}%",
        correct,
        test_idx.len(),
        100.0 * correct as f64 / test_idx.len() as f64
    );
    if use_mlp {
        let first = mlp_loss_head.first().copied().unwrap_or(f32::NAN);
        let last = mlp_loss_tail.last().copied().unwrap_or(f32::NAN);
        println!(
            "      MLP loss curve     : {first:.3} -> {last:.3} ({} artifacts-trained steps)",
            if last < first { "converging" } else { "check" }
        );
    }
    Ok(())
}

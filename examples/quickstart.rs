//! Quickstart: the whole system in ~60 lines.
//!
//! 1. Generate a small synthetic collection.
//! 2. Sweep it (reorder × solve) to build a labeled dataset.
//! 3. Train the Random-Forest selector.
//! 4. Predict + solve a fresh matrix through the selection pipeline.
//!
//! Run: `cargo run --release --example quickstart`

use smr::collection::generate_mini_collection;
use smr::coordinator::{train_forest, SelectionPipeline};
use smr::dataset::{build_dataset, SweepConfig};
use smr::ml::normalize::Method;
use smr::reorder::ReorderAlgorithm;
use smr::solver::SolverConfig;

fn main() -> anyhow::Result<()> {
    // 1. a small collection (6 families x 4 sizes)
    let collection = generate_mini_collection(42, 4);
    println!("collection: {} matrices", collection.len());

    // 2. label each matrix with its fastest reordering algorithm
    let dataset = build_dataset(
        &collection,
        &ReorderAlgorithm::LABEL_SET,
        &SweepConfig::default(),
    );
    println!(
        "dataset built; label distribution [AMD, SCOTCH, ND, RCM] = {:?}",
        dataset.label_distribution()
    );

    // 3. train the selector (grid search + 5-fold CV, like the paper)
    let (train_idx, test_idx) = dataset.split(0.8, 42);
    let trained = train_forest(&dataset, &train_idx, Method::Standard, 42);
    println!(
        "forest trained: CV accuracy {:.2}, best params {:?}",
        trained.grid.best_cv_accuracy, trained.grid.best_params
    );
    let test_acc = smr::coordinator::trainer::eval_classifier(
        &trained.forest,
        &trained.normalizer,
        &dataset,
        &test_idx,
    );
    println!("test accuracy: {:.2}", test_acc);

    // 4. end-to-end: predict the ordering for a new matrix and solve
    let pipeline = SelectionPipeline::new(
        trained.normalizer,
        Box::new(trained.forest),
        SolverConfig::default(),
    );
    let fresh = smr::collection::generators::grid2d(40, 40);
    let report = pipeline.run(&fresh);
    println!(
        "fresh 40x40 grid -> predicted {} | prediction {:.3}ms | solve {:.3}ms | residual {:.1e}",
        report.algorithm,
        report.prediction_s() * 1e3,
        report.solve.total_s() * 1e3,
        report.solve.residual
    );
    Ok(())
}

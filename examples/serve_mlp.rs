//! End-to-end serving demo with the AOT MLP (Pallas kernels via PJRT):
//! train the MLP through the AOT train-step executable, stand up the
//! batched prediction service, fire concurrent requests at it, and report
//! latency/throughput — the serving-paper-style driver for this system.
//!
//! Requires artifacts: `make artifacts` first.
//! Run: `cargo run --release --example serve_mlp`

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use smr::collection::generate_mini_collection;
use smr::coordinator::service::Backend;
use smr::coordinator::{train_mlp, BatcherConfig, PredictionService};
use smr::dataset::{build_dataset, SweepConfig};
use smr::features;
use smr::model::TrainConfig;
use smr::reorder::ReorderAlgorithm;
use smr::runtime::{Manifest, Runtime};
use smr::util::stats;

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("artifacts/manifest.json missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // dataset + MLP training through the AOT train-step executable
    let collection = generate_mini_collection(7, 4);
    let dataset = build_dataset(
        &collection,
        &ReorderAlgorithm::LABEL_SET,
        &SweepConfig::default(),
    );
    let (train_idx, test_idx) = dataset.split(0.8, 7);
    let trained = {
        let runtime = Runtime::cpu()?;
        println!("PJRT platform: {}", runtime.platform());
        let manifest = Manifest::load(artifacts)?;
        println!(
            "artifacts: {} ({} archs)",
            manifest.artifacts.len(),
            manifest.archs().len()
        );
        let cfg = TrainConfig {
            epochs: 80,
            ..Default::default()
        };
        train_mlp(&runtime, &manifest, &dataset, &train_idx, &cfg)?
    };
    println!(
        "MLP[{}] trained: val accuracy {:.2}, final loss {:.3}",
        trained.arch,
        trained.val_accuracy,
        trained.losses.last().copied().unwrap_or(f32::NAN)
    );

    // serving: dedicated runtime thread + dynamic batcher
    let svc = Arc::new(PredictionService::spawn(
        Backend::Mlp {
            artifacts_dir: artifacts.to_path_buf(),
            model: trained.model,
        },
        BatcherConfig {
            max_batch: 64,
            max_wait: std::time::Duration::from_millis(2),
        },
    )?);

    // concurrent client load: 8 client threads x 50 requests
    let feats: Vec<Vec<f64>> = collection
        .iter()
        .map(|m| features::extract(&m.matrix).to_vec())
        .collect();
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..8 {
        let svc = svc.clone();
        let feats = feats.clone();
        handles.push(std::thread::spawn(move || {
            let mut lat = Vec::new();
            for k in 0..50 {
                let t = Instant::now();
                let _alg = svc.predict(&feats[(c * 50 + k) % feats.len()]).unwrap();
                lat.push(t.elapsed().as_secs_f64());
            }
            lat
        }));
    }
    let mut latencies = Vec::new();
    for h in handles {
        latencies.extend(h.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {} concurrent predictions in {:.3}s -> {:.0} req/s",
        latencies.len(),
        wall,
        latencies.len() as f64 / wall
    );
    println!(
        "latency p50 {:.2}ms  p99 {:.2}ms  mean batch size {:.1}",
        stats::percentile(&latencies, 50.0) * 1e3,
        stats::percentile(&latencies, 99.0) * 1e3,
        svc.stats.mean_batch_size()
    );

    // sanity: test-split accuracy served through the batcher
    let all_x = dataset.features();
    let mut correct = 0;
    for &i in &test_idx {
        let alg = svc.predict(&all_x[i])?;
        if alg.label_index() == Some(dataset.records[i].label) {
            correct += 1;
        }
    }
    println!(
        "served test accuracy: {}/{} (same model as offline eval)",
        correct,
        test_idx.len()
    );
    Ok(())
}

//! End-to-end serving demo with the AOT MLP (Pallas kernels via PJRT):
//! train the MLP through the AOT train-step executable, stand the full
//! `ServingEngine` up on it (batched prediction service + pattern-keyed
//! symbolic-plan and ordering caches + pooled workspaces), fire
//! concurrent *matrix* requests at it, and report cold/warm latency,
//! cache hit rates, and workspace reuse — the serving-paper-style
//! driver for this system.
//!
//! Requires artifacts: `make artifacts` first.
//! Run: `cargo run --release --example serve_mlp`

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use smr::collection::generate_mini_collection;
use smr::coordinator::service::Backend;
use smr::coordinator::{train_mlp, BatcherConfig, ServingConfig, ServingEngine};
use smr::dataset::{build_dataset, SweepConfig};
use smr::model::TrainConfig;
use smr::reorder::ReorderAlgorithm;
use smr::runtime::{Manifest, Runtime};
use smr::util::stats;

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("artifacts/manifest.json missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // dataset + MLP training through the AOT train-step executable
    let collection = generate_mini_collection(7, 4);
    let dataset = build_dataset(
        &collection,
        &ReorderAlgorithm::LABEL_SET,
        &SweepConfig::default(),
    );
    let (train_idx, _test_idx) = dataset.split(0.8, 7);
    let trained = {
        let runtime = Runtime::cpu()?;
        println!("PJRT platform: {}", runtime.platform());
        let manifest = Manifest::load(artifacts)?;
        println!(
            "artifacts: {} ({} archs)",
            manifest.artifacts.len(),
            manifest.archs().len()
        );
        let cfg = TrainConfig {
            epochs: 80,
            ..Default::default()
        };
        train_mlp(&runtime, &manifest, &dataset, &train_idx, &cfg)?
    };
    println!(
        "MLP[{}] trained: val accuracy {:.2}, final loss {:.3}",
        trained.arch,
        trained.val_accuracy,
        trained.losses.last().copied().unwrap_or(f32::NAN)
    );

    // the serving engine: batched MLP predictions + ordering cache +
    // pooled workspaces behind one object
    let engine = Arc::new(ServingEngine::spawn(
        Backend::Mlp {
            artifacts_dir: artifacts.to_path_buf(),
            model: trained.model,
        },
        ServingConfig {
            batcher: BatcherConfig {
                max_batch: 64,
                max_wait: std::time::Duration::from_millis(2),
            },
            ..Default::default()
        },
    )?);

    // cold pass: every pattern is new — orderings are computed and
    // solve plans are frozen into the plan cache
    let t0 = Instant::now();
    for nm in collection.iter() {
        let r = engine.serve(&nm.matrix)?;
        assert!(!r.plan_hit);
    }
    let cold_wall = t0.elapsed().as_secs_f64();

    // warm concurrent client load: 8 client threads x 50 requests over
    // the same patterns — steady state is all cache hits
    let t0 = Instant::now();
    let matrices: Arc<Vec<_>> =
        Arc::new(collection.iter().map(|nm| nm.matrix.clone()).collect());
    let mut handles = Vec::new();
    for c in 0..8usize {
        let engine = engine.clone();
        let matrices = matrices.clone();
        handles.push(std::thread::spawn(move || {
            let mut lat = Vec::new();
            for k in 0..50 {
                let t = Instant::now();
                let r = engine.serve(&matrices[(c * 50 + k) % matrices.len()]).unwrap();
                assert!(ReorderAlgorithm::LABEL_SET.contains(&r.algorithm));
                lat.push(t.elapsed().as_secs_f64());
            }
            lat
        }));
    }
    let mut latencies = Vec::new();
    for h in handles {
        latencies.extend(h.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "cold pass: {} requests in {:.3}s | warm: {} concurrent requests in {:.3}s -> {:.0} req/s",
        collection.len(),
        cold_wall,
        latencies.len(),
        wall,
        latencies.len() as f64 / wall
    );
    println!(
        "warm latency p50 {:.2}ms  p99 {:.2}ms",
        stats::percentile(&latencies, 50.0) * 1e3,
        stats::percentile(&latencies, 99.0) * 1e3,
    );

    let s = engine.stats();
    println!(
        "stats: {} requests | plans {} hits / {} misses / {} evictions ({:.1}% hit) | \
         orderings {} hits / {} misses | workspaces {} checkouts ({} created, {} reused) | \
         {} predict batches (mean {:.1})",
        s.requests,
        s.plans.hits,
        s.plans.misses,
        s.plans.evictions,
        100.0 * s.plans.hit_rate(),
        s.cache.hits,
        s.cache.misses,
        s.workspaces.checkouts,
        s.workspaces.creates,
        s.workspaces.reuses,
        s.service.batches,
        s.service.mean_batch_size,
    );
    Ok(())
}

//! Reordering explorer: compare all eight orderings on one matrix —
//! bandwidth, profile, symbolic fill/flops, measured factor time.
//!
//! Usage:
//!   cargo run --release --example reorder_explorer              # built-in demo matrix
//!   cargo run --release --example reorder_explorer -- file.mtx  # your matrix

use smr::graph::Graph;
use smr::reorder::{metrics, ReorderAlgorithm};
use smr::solver::{prepare, solve_ordered, SolverConfig};
use smr::sparse::matrix_market;
use smr::util::table::Table;
use smr::util::Timer;

fn main() -> anyhow::Result<()> {
    let arg = std::env::args().nth(1);
    let (name, matrix) = match &arg {
        Some(path) => (
            path.clone(),
            matrix_market::read_file(std::path::Path::new(path))?,
        ),
        None => (
            "demo: scrambled banded + circuit hub".to_string(),
            demo_matrix(),
        ),
    };
    println!(
        "{name}: {}x{}, {} nnz, pattern-symmetric: {}",
        matrix.nrows,
        matrix.ncols,
        matrix.nnz(),
        matrix.is_pattern_symmetric()
    );

    let cfg = SolverConfig::default();
    let spd = prepare(&matrix, &cfg);
    let algorithms = [
        ReorderAlgorithm::Natural,
        ReorderAlgorithm::Cm,
        ReorderAlgorithm::Rcm,
        ReorderAlgorithm::Md,
        ReorderAlgorithm::Amd,
        ReorderAlgorithm::Amf,
        ReorderAlgorithm::Qamd,
        ReorderAlgorithm::Nd,
        ReorderAlgorithm::Scotch,
        ReorderAlgorithm::Pord,
    ];

    let mut t = Table::new(&[
        "Algorithm",
        "reorder(ms)",
        "bandwidth",
        "profile",
        "fill nnz(L)",
        "flops",
        "factor+solve(ms)",
    ]);
    let g = Graph::from_matrix(&spd);
    for alg in algorithms {
        let timer = Timer::start();
        let perm = alg.compute_on_graph(&g, 42);
        let reorder_ms = timer.elapsed_ms();
        let cost = metrics::symbolic_cost_under(&spd, &perm);
        let report = solve_ordered(&spd, &perm, &cfg)?;
        t.row(vec![
            alg.name().to_string(),
            format!("{reorder_ms:.2}"),
            metrics::bandwidth_under(&spd, &perm).to_string(),
            metrics::profile_under(&spd, &perm).to_string(),
            cost.fill.to_string(),
            format!("{:.2e}", cost.flops),
            format!(
                "{:.2}{}",
                (report.factor_s + report.solve_s) * 1e3,
                if report.estimated { "*" } else { "" }
            ),
        ]);
    }
    t.print();
    println!("(* = flop-cap estimate)");
    Ok(())
}

/// Demo matrix mixing two structures: a scrambled band (RCM's home turf)
/// bridged to a hub cluster (minimum degree's home turf).
fn demo_matrix() -> smr::sparse::CsrMatrix {
    use smr::util::rng::Rng;
    let mut rng = Rng::new(1234);
    let band = smr::collection::generators::scrambled_banded(600, 3, &mut rng);
    let hub = smr::collection::generators::circuit(300, 3, &mut rng);
    // block-diagonal combine + a few bridges
    let n = band.nrows + hub.nrows;
    let mut coo = smr::sparse::CooMatrix::with_capacity(n, n, band.nnz() + hub.nnz() + 8);
    for r in 0..band.nrows {
        for (k, &c) in band.row_indices(r).iter().enumerate() {
            coo.push(r, c, band.row_data(r)[k]);
        }
    }
    for r in 0..hub.nrows {
        for (k, &c) in hub.row_indices(r).iter().enumerate() {
            coo.push(band.nrows + r, band.nrows + c, hub.row_data(r)[k]);
        }
    }
    for b in 0..4 {
        coo.push_sym(b * 150, band.nrows + b * 70, -0.5);
    }
    coo.to_csr()
}

//! Schema sanity-checker for `BENCH_*.json` artifacts (used by `ci.sh`).
//!
//! Usage: `cargo run --release --example check_bench -- BENCH_serving.json ...`
//!
//! Every argument must parse as a bench artifact: a JSON object with a
//! non-empty `results` array of records. For `bench_serving` artifacts
//! the serving schema is enforced too: per-record cold/warm latencies,
//! the `warm_alloc_free` arena flag, top-level cache hit/miss/evict
//! plus front-arena counters, and the batched warm path (a non-empty
//! `batched` burst array plus the engine's `batches` coalescing
//! counters, the plan/ordering caches' in-flight dedup counters, and
//! the per-stage `latency` quantiles). For `bench_router` artifacts
//! every lane must report throughput, p50/p99/p999 tail latency, fleet
//! dedup counters, and a per-replica occupancy array, with both
//! closed- and open-loop lanes present. For `bench_online` artifacts
//! the windowed regret curve (>= 2 windows), per-algorithm pick
//! histogram, fixed-policy baselines, learner counter block, and the
//! `regret_improved` flag are all required. For `bench_solver` artifacts every record must carry the
//! `peak_front_bytes` / `allocs` columns, the replay lanes
//! (`planned_numeric`, `arena_numeric`, `pipelined`) and the
//! `batched_warm` lane (with its `batch_k` / `per_request_s` /
//! `throughput_per_s` amortization columns) must all be present, and
//! at least one `core_scaling_w*` lane must report the worker sweep.
//! Exits non-zero (listing every violation) on malformed
//! input, so a bench that wrote garbage fails CI instead of silently
//! polluting the perf trajectory.

use smr::util::json::{self, Json};

fn check_num(obj: &Json, key: &str, errs: &mut Vec<String>, ctx: &str) {
    match obj.get(key).and_then(|v| v.as_f64()) {
        Some(v) if v.is_finite() => {}
        Some(v) => errs.push(format!("{ctx}: `{key}` is not finite ({v})")),
        None => errs.push(format!("{ctx}: missing numeric `{key}`")),
    }
}

fn check_bool(obj: &Json, key: &str, errs: &mut Vec<String>, ctx: &str) {
    if obj.get(key).and_then(|v| v.as_bool()).is_none() {
        errs.push(format!("{ctx}: missing boolean `{key}`"));
    }
}

fn check_file(path: &str) -> Vec<String> {
    let mut errs = Vec::new();
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => return vec![format!("{path}: unreadable: {e}")],
    };
    let v = match json::parse(&text) {
        Ok(v) => v,
        Err(e) => return vec![format!("{path}: invalid JSON: {e}")],
    };
    let Some(results) = v.get("results").and_then(|r| r.as_arr()) else {
        return vec![format!("{path}: missing `results` array")];
    };
    if results.is_empty() {
        errs.push(format!("{path}: empty `results`"));
    }
    for (i, rec) in results.iter().enumerate() {
        if rec.get("name").and_then(|n| n.as_str()).is_none() {
            errs.push(format!("{path}: results[{i}]: missing string `name`"));
        }
    }

    // solver-specific schema: arena columns on every record, and the
    // three numeric-replay lanes all present
    if v.get("bench").and_then(|b| b.as_str()) == Some("bench_solver") {
        let mut lanes: Vec<&str> = Vec::new();
        for (i, rec) in results.iter().enumerate() {
            let ctx = format!("{path}: results[{i}]");
            for key in ["n", "nnz", "wall_s", "peak_front_bytes", "allocs"] {
                check_num(rec, key, &mut errs, &ctx);
            }
            if let Some(mode) = rec.get("mode").and_then(|m| m.as_str()) {
                lanes.push(mode);
                // batched lanes carry the multi-RHS amortization columns
                if mode == "batched_warm" {
                    for key in ["batch_k", "per_request_s", "throughput_per_s"] {
                        check_num(rec, key, &mut errs, &ctx);
                    }
                }
            }
        }
        for lane in ["planned_numeric", "arena_numeric", "pipelined", "batched_warm"] {
            if !lanes.contains(&lane) {
                errs.push(format!("{path}: missing `{lane}` lane in results"));
            }
        }
        if !lanes.iter().any(|l| l.starts_with("core_scaling_w")) {
            errs.push(format!("{path}: missing `core_scaling_w*` lanes in results"));
        }
        match v.get("fronts") {
            Some(fr) => {
                for key in ["checkouts", "creates", "reuses", "grows"] {
                    check_num(fr, key, &mut errs, &format!("{path}: fronts"));
                }
            }
            None => errs.push(format!("{path}: missing `fronts` object")),
        }
    }

    // serving-specific schema
    if v.get("bench").and_then(|b| b.as_str()) == Some("bench_serving") {
        for (i, rec) in results.iter().enumerate() {
            let ctx = format!("{path}: results[{i}]");
            for key in ["n", "nnz", "cold_s", "warm_s", "speedup", "numeric_only_s"] {
                check_num(rec, key, &mut errs, &ctx);
            }
            check_bool(rec, "warm_alloc_free", &mut errs, &ctx);
        }
        match v.get("fronts") {
            Some(fr) => {
                for key in ["checkouts", "creates", "reuses", "grows"] {
                    check_num(fr, key, &mut errs, &format!("{path}: fronts"));
                }
            }
            None => errs.push(format!("{path}: missing `fronts` object")),
        }
        // symbolic-plan cache counters (the warm path's cache layer),
        // including the in-flight dedup pair (leaders / coalesced)
        match v.get("plans") {
            Some(plans) => {
                for key in [
                    "hits", "misses", "evictions", "inserts", "hit_rate", "leaders", "coalesced",
                ] {
                    check_num(plans, key, &mut errs, &format!("{path}: plans"));
                }
            }
            None => errs.push(format!("{path}: missing `plans` object")),
        }
        match v.get("cache") {
            Some(cache) => {
                for key in [
                    "hits", "misses", "evictions", "inserts", "hit_rate", "leaders", "coalesced",
                ] {
                    check_num(cache, key, &mut errs, &format!("{path}: cache"));
                }
            }
            None => errs.push(format!("{path}: missing `cache` object")),
        }
        // per-stage latency histograms folded into the stat block
        match v.get("latency") {
            Some(lat) => {
                for key in ["count", "p50_s", "p99_s", "p999_s"] {
                    check_num(lat, key, &mut errs, &format!("{path}: latency"));
                }
            }
            None => errs.push(format!("{path}: missing `latency` object")),
        }
        match v.get("workspaces") {
            Some(ws) => {
                for key in ["checkouts", "creates", "reuses"] {
                    check_num(ws, key, &mut errs, &format!("{path}: workspaces"));
                }
            }
            None => errs.push(format!("{path}: missing `workspaces` object")),
        }
        // batched warm path: burst records + engine coalescing counters
        match v.get("batched").and_then(|b| b.as_arr()) {
            Some(recs) if !recs.is_empty() => {
                for (i, rec) in recs.iter().enumerate() {
                    let ctx = format!("{path}: batched[{i}]");
                    for key in ["batch_k", "batch_s", "per_request_s", "throughput_per_s"] {
                        check_num(rec, key, &mut errs, &ctx);
                    }
                }
            }
            _ => errs.push(format!("{path}: missing non-empty `batched` array")),
        }
        match v.get("batches") {
            Some(bt) => {
                for key in ["batches", "coalesced", "window_timeouts"] {
                    check_num(bt, key, &mut errs, &format!("{path}: batches"));
                }
                if bt.get("size_hist").and_then(|h| h.as_arr()).is_none() {
                    errs.push(format!("{path}: batches: missing `size_hist` array"));
                }
            }
            None => errs.push(format!("{path}: missing `batches` object")),
        }
        check_num(&v, "requests", &mut errs, path);
    }

    // router-specific schema: every lane carries throughput + tail
    // latency + the fleet dedup counters, plus a non-empty per-replica
    // array with admission-gate occupancy high-water marks; both loop
    // modes must be present
    if v.get("bench").and_then(|b| b.as_str()) == Some("bench_router") {
        let mut modes: Vec<&str> = Vec::new();
        for (i, rec) in results.iter().enumerate() {
            let ctx = format!("{path}: results[{i}]");
            for key in [
                "replicas",
                "requests",
                "ok",
                "rejected",
                "throughput_per_s",
                "p50_s",
                "p99_s",
                "p999_s",
                "plan_hit_rate",
                "leaders",
                "coalesced",
            ] {
                check_num(rec, key, &mut errs, &ctx);
            }
            match rec.get("mode").and_then(|m| m.as_str()) {
                Some(mode) => modes.push(mode),
                None => errs.push(format!("{ctx}: missing string `mode`")),
            }
            match rec.get("per_replica").and_then(|r| r.as_arr()) {
                Some(reps) if !reps.is_empty() => {
                    for (j, rep) in reps.iter().enumerate() {
                        let rctx = format!("{ctx}: per_replica[{j}]");
                        for key in ["replica", "requests", "occupancy_hwm"] {
                            check_num(rep, key, &mut errs, &rctx);
                        }
                    }
                }
                _ => errs.push(format!("{ctx}: missing non-empty `per_replica` array")),
            }
        }
        for mode in ["closed", "open"] {
            if !modes.contains(&mode) {
                errs.push(format!("{path}: missing `{mode}`-loop lanes in results"));
            }
        }
        for key in ["patterns", "zipf_s", "trace_len", "workers"] {
            check_num(&v, key, &mut errs, path);
        }
    }
    // online-learning schema: a windowed regret curve (>= 2 windows so
    // first-vs-final regret is meaningful), the pick histogram, the
    // fixed-policy baselines, the learner counter block, and the
    // headline `regret_improved` flag
    if v.get("bench").and_then(|b| b.as_str()) == Some("bench_online") {
        if results.len() < 2 {
            errs.push(format!(
                "{path}: need >= 2 window records for a regret curve"
            ));
        }
        for (i, rec) in results.iter().enumerate() {
            let ctx = format!("{path}: results[{i}]");
            for key in [
                "window",
                "requests",
                "regret_s",
                "regret_per_req_s",
                "explored",
                "exploited",
            ] {
                check_num(rec, key, &mut errs, &ctx);
            }
        }
        match v.get("picks").and_then(|p| p.as_arr()) {
            Some(picks) if !picks.is_empty() => {
                for (i, p) in picks.iter().enumerate() {
                    let pctx = format!("{path}: picks[{i}]");
                    if p.get("algorithm").and_then(|a| a.as_str()).is_none() {
                        errs.push(format!("{pctx}: missing string `algorithm`"));
                    }
                    check_num(p, "picked", &mut errs, &pctx);
                }
            }
            _ => errs.push(format!("{path}: missing non-empty `picks` array")),
        }
        match v.get("baselines") {
            Some(b) => {
                for key in [
                    "oracle_total_s",
                    "amd_regret_s",
                    "model_regret_s",
                    "learner_regret_s",
                ] {
                    check_num(b, key, &mut errs, &format!("{path}: baselines"));
                }
            }
            None => errs.push(format!("{path}: missing `baselines` object")),
        }
        match v.get("learner") {
            Some(l) => {
                for key in [
                    "decisions",
                    "explored",
                    "observations",
                    "updates",
                    "dropped",
                    "regret_s",
                ] {
                    check_num(l, key, &mut errs, &format!("{path}: learner"));
                }
            }
            None => errs.push(format!("{path}: missing `learner` object")),
        }
        for key in [
            "patterns",
            "zipf_s",
            "trace_len",
            "window",
            "first_window_regret_s",
            "final_window_regret_s",
        ] {
            check_num(&v, key, &mut errs, path);
        }
        check_bool(&v, "regret_improved", &mut errs, path);
    }
    errs
}

fn main() {
    let paths: Vec<String> = std::env::args().skip(1).collect();
    if paths.is_empty() {
        eprintln!("usage: check_bench <BENCH_*.json> ...");
        std::process::exit(2);
    }
    let mut failed = false;
    for path in &paths {
        let errs = check_file(path);
        if errs.is_empty() {
            println!("{path}: ok");
        } else {
            failed = true;
            for e in &errs {
                eprintln!("{e}");
            }
        }
    }
    if failed {
        std::process::exit(1);
    }
}

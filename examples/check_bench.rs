//! Schema sanity-checker for `BENCH_*.json` artifacts (used by `ci.sh`).
//!
//! Usage: `cargo run --release --example check_bench -- BENCH_serving.json ...`
//!
//! Every argument must parse as a bench artifact: a JSON object with a
//! non-empty `results` array of records. The `bench` tag dispatches to
//! one per-bench checker — each bench's schema is validated
//! independently, so adding or tightening one bench's schema can never
//! break another artifact's gate:
//!
//! * `bench_solver` — per-record arena columns (`peak_front_bytes`,
//!   `allocs`), the numeric-replay lanes (`planned_numeric`,
//!   `arena_numeric`, `pipelined`, `batched_warm` with its amortization
//!   columns), and at least one `core_scaling_w*` lane;
//! * `bench_serving` — per-record cold/warm latencies and the
//!   `warm_alloc_free` arena flag, plus the cache/pool/latency/batching
//!   stat sections;
//! * `bench_router` — per-lane throughput + tail latency + fleet dedup
//!   counters, per-replica occupancy, both loop modes;
//! * `bench_online` — windowed regret curve (>= 2 windows), pick
//!   histogram, fixed-policy baselines, learner counters, and the
//!   `regret_improved` flag;
//! * `bench_replan` — per-drift-size repair-vs-cold latency records and
//!   the `serving` drifting-trace counter block (`repairs`,
//!   `repair_fallbacks`, hits/misses, `repair_rate`) proving the repair
//!   tier resolved drift without silent fallback;
//! * `bench_faults` — one record per injected-fault-rate lane (a
//!   fault-free baseline plus escalating rates) carrying goodput,
//!   fallback rate, tail latency, and the exact fault ledger
//!   (injected / fired / fallbacks / quarantine counters, zero errors).
//!
//! **Optional sections.** A bench's stat sections beyond the per-record
//! schema (`fronts`, `batched`, `latency`, …) are gated through a
//! top-level `sections` string array when the artifact carries one: a
//! declared section must be present (and valid), an undeclared one is
//! validated only if present — so a bench run that legitimately skips an
//! optional lane no longer hard-fails the whole artifact. Artifacts
//! without a `sections` field keep the legacy-strict behavior (every
//! section their bench defines is required). Exits non-zero (listing
//! every violation) on malformed input, so a bench that wrote garbage
//! fails CI instead of silently polluting the perf trajectory.

use smr::util::json::{self, Json};

fn check_num(obj: &Json, key: &str, errs: &mut Vec<String>, ctx: &str) {
    match obj.get(key).and_then(|v| v.as_f64()) {
        Some(v) if v.is_finite() => {}
        Some(v) => errs.push(format!("{ctx}: `{key}` is not finite ({v})")),
        None => errs.push(format!("{ctx}: missing numeric `{key}`")),
    }
}

fn check_bool(obj: &Json, key: &str, errs: &mut Vec<String>, ctx: &str) {
    if obj.get(key).and_then(|v| v.as_bool()).is_none() {
        errs.push(format!("{ctx}: missing boolean `{key}`"));
    }
}

/// The artifact's declared optional sections (top-level `sections`
/// string array). `None` = legacy artifact: every section its bench
/// defines is required.
struct Sections {
    declared: Option<Vec<String>>,
}

impl Sections {
    fn of(v: &Json) -> Sections {
        Sections {
            declared: v.get("sections").and_then(|s| s.as_arr()).map(|arr| {
                arr.iter()
                    .filter_map(|s| s.as_str().map(str::to_string))
                    .collect()
            }),
        }
    }

    /// Is `name` required to be present? Declared sections and every
    /// section of a legacy (no `sections` field) artifact are.
    fn requires(&self, name: &str) -> bool {
        match &self.declared {
            None => true,
            Some(d) => d.iter().any(|s| s == name),
        }
    }
}

/// Validate a stat-object section: all `keys` numeric when the section
/// is present; its absence is an error only when the artifact requires
/// it (see [`Sections`]).
fn check_section(
    v: &Json,
    sections: &Sections,
    name: &str,
    keys: &[&str],
    errs: &mut Vec<String>,
    path: &str,
) {
    match v.get(name) {
        Some(sec) => {
            for key in keys {
                check_num(sec, key, errs, &format!("{path}: {name}"));
            }
        }
        None if sections.requires(name) => errs.push(format!("{path}: missing `{name}` object")),
        None => {}
    }
}

/// Solver schema: arena columns on every record, and the numeric-replay
/// lanes all present.
fn check_solver(path: &str, v: &Json, results: &[Json], errs: &mut Vec<String>) {
    let sections = Sections::of(v);
    let mut lanes: Vec<&str> = Vec::new();
    for (i, rec) in results.iter().enumerate() {
        let ctx = format!("{path}: results[{i}]");
        for key in ["n", "nnz", "wall_s", "peak_front_bytes", "allocs"] {
            check_num(rec, key, errs, &ctx);
        }
        if let Some(mode) = rec.get("mode").and_then(|m| m.as_str()) {
            lanes.push(mode);
            // batched lanes carry the multi-RHS amortization columns
            if mode == "batched_warm" {
                for key in ["batch_k", "per_request_s", "throughput_per_s"] {
                    check_num(rec, key, errs, &ctx);
                }
            }
        }
    }
    for lane in ["planned_numeric", "arena_numeric", "pipelined", "batched_warm"] {
        if !lanes.contains(&lane) {
            errs.push(format!("{path}: missing `{lane}` lane in results"));
        }
    }
    if !lanes.iter().any(|l| l.starts_with("core_scaling_w")) {
        errs.push(format!("{path}: missing `core_scaling_w*` lanes in results"));
    }
    check_section(
        v,
        &sections,
        "fronts",
        &["checkouts", "creates", "reuses", "grows"],
        errs,
        path,
    );
}

/// Serving schema: per-record cold/warm latencies + arena flag, cache
/// and pool stat sections, the batched warm path, latency quantiles.
fn check_serving(path: &str, v: &Json, results: &[Json], errs: &mut Vec<String>) {
    let sections = Sections::of(v);
    for (i, rec) in results.iter().enumerate() {
        let ctx = format!("{path}: results[{i}]");
        for key in ["n", "nnz", "cold_s", "warm_s", "speedup", "numeric_only_s"] {
            check_num(rec, key, errs, &ctx);
        }
        check_bool(rec, "warm_alloc_free", errs, &ctx);
    }
    check_section(
        v,
        &sections,
        "fronts",
        &["checkouts", "creates", "reuses", "grows"],
        errs,
        path,
    );
    // symbolic-plan cache counters (the warm path's cache layer),
    // including the in-flight dedup pair (leaders / coalesced)
    let cache_keys = [
        "hits", "misses", "evictions", "inserts", "hit_rate", "leaders", "coalesced",
    ];
    check_section(v, &sections, "plans", &cache_keys, errs, path);
    check_section(v, &sections, "cache", &cache_keys, errs, path);
    // per-stage latency histograms folded into the stat block
    check_section(
        v,
        &sections,
        "latency",
        &["count", "p50_s", "p99_s", "p999_s"],
        errs,
        path,
    );
    check_section(
        v,
        &sections,
        "workspaces",
        &["checkouts", "creates", "reuses"],
        errs,
        path,
    );
    // batched warm path: burst records + engine coalescing counters
    match v.get("batched").and_then(|b| b.as_arr()) {
        Some(recs) if !recs.is_empty() => {
            for (i, rec) in recs.iter().enumerate() {
                let ctx = format!("{path}: batched[{i}]");
                for key in ["batch_k", "batch_s", "per_request_s", "throughput_per_s"] {
                    check_num(rec, key, errs, &ctx);
                }
            }
        }
        Some(_) => errs.push(format!("{path}: empty `batched` array")),
        None if sections.requires("batched") => {
            errs.push(format!("{path}: missing non-empty `batched` array"))
        }
        None => {}
    }
    match v.get("batches") {
        Some(bt) => {
            for key in ["batches", "coalesced", "window_timeouts"] {
                check_num(bt, key, errs, &format!("{path}: batches"));
            }
            if bt.get("size_hist").and_then(|h| h.as_arr()).is_none() {
                errs.push(format!("{path}: batches: missing `size_hist` array"));
            }
        }
        None if sections.requires("batches") => {
            errs.push(format!("{path}: missing `batches` object"))
        }
        None => {}
    }
    check_num(v, "requests", errs, path);
}

/// Router schema: every lane carries throughput + tail latency + fleet
/// dedup counters, plus a non-empty per-replica array with occupancy
/// high-water marks; both loop modes must be present.
fn check_router(path: &str, v: &Json, results: &[Json], errs: &mut Vec<String>) {
    let mut modes: Vec<&str> = Vec::new();
    for (i, rec) in results.iter().enumerate() {
        let ctx = format!("{path}: results[{i}]");
        for key in [
            "replicas",
            "requests",
            "ok",
            "rejected",
            "throughput_per_s",
            "p50_s",
            "p99_s",
            "p999_s",
            "plan_hit_rate",
            "leaders",
            "coalesced",
        ] {
            check_num(rec, key, errs, &ctx);
        }
        match rec.get("mode").and_then(|m| m.as_str()) {
            Some(mode) => modes.push(mode),
            None => errs.push(format!("{ctx}: missing string `mode`")),
        }
        match rec.get("per_replica").and_then(|r| r.as_arr()) {
            Some(reps) if !reps.is_empty() => {
                for (j, rep) in reps.iter().enumerate() {
                    let rctx = format!("{ctx}: per_replica[{j}]");
                    for key in ["replica", "requests", "occupancy_hwm"] {
                        check_num(rep, key, errs, &rctx);
                    }
                }
            }
            _ => errs.push(format!("{ctx}: missing non-empty `per_replica` array")),
        }
    }
    for mode in ["closed", "open"] {
        if !modes.contains(&mode) {
            errs.push(format!("{path}: missing `{mode}`-loop lanes in results"));
        }
    }
    for key in ["patterns", "zipf_s", "trace_len", "workers"] {
        check_num(v, key, errs, path);
    }
}

/// Online-learning schema: a windowed regret curve (>= 2 windows so
/// first-vs-final regret is meaningful), the pick histogram, the
/// fixed-policy baselines, the learner counter block, and the headline
/// `regret_improved` flag.
fn check_online(path: &str, v: &Json, results: &[Json], errs: &mut Vec<String>) {
    let sections = Sections::of(v);
    if results.len() < 2 {
        errs.push(format!(
            "{path}: need >= 2 window records for a regret curve"
        ));
    }
    for (i, rec) in results.iter().enumerate() {
        let ctx = format!("{path}: results[{i}]");
        for key in [
            "window",
            "requests",
            "regret_s",
            "regret_per_req_s",
            "explored",
            "exploited",
        ] {
            check_num(rec, key, errs, &ctx);
        }
    }
    match v.get("picks").and_then(|p| p.as_arr()) {
        Some(picks) if !picks.is_empty() => {
            for (i, p) in picks.iter().enumerate() {
                let pctx = format!("{path}: picks[{i}]");
                if p.get("algorithm").and_then(|a| a.as_str()).is_none() {
                    errs.push(format!("{pctx}: missing string `algorithm`"));
                }
                check_num(p, "picked", errs, &pctx);
            }
        }
        _ => errs.push(format!("{path}: missing non-empty `picks` array")),
    }
    check_section(
        v,
        &sections,
        "baselines",
        &[
            "oracle_total_s",
            "amd_regret_s",
            "model_regret_s",
            "learner_regret_s",
        ],
        errs,
        path,
    );
    check_section(
        v,
        &sections,
        "learner",
        &[
            "decisions",
            "explored",
            "observations",
            "updates",
            "dropped",
            "regret_s",
        ],
        errs,
        path,
    );
    for key in [
        "patterns",
        "zipf_s",
        "trace_len",
        "window",
        "first_window_regret_s",
        "final_window_regret_s",
    ] {
        check_num(v, key, errs, path);
    }
    check_bool(v, "regret_improved", errs, path);
}

/// Incremental-replanning schema: one record per drift size comparing
/// cold re-analysis to plan repair, plus the drifting-trace serving
/// counters — `repairs` / `repair_fallbacks` are the "no silent
/// fallback" ledger the repair tier is accepted on.
fn check_replan(path: &str, v: &Json, results: &[Json], errs: &mut Vec<String>) {
    let sections = Sections::of(v);
    for (i, rec) in results.iter().enumerate() {
        let ctx = format!("{path}: results[{i}]");
        for key in ["drift_edges", "cold_s", "repair_s", "speedup"] {
            check_num(rec, key, errs, &ctx);
        }
    }
    for key in ["n", "nnz"] {
        check_num(v, key, errs, path);
    }
    check_section(
        v,
        &sections,
        "serving",
        &[
            "requests",
            "drift_steps",
            "repairs",
            "repair_fallbacks",
            "hits",
            "misses",
            "repair_rate",
            "cold_serve_s",
            "repair_serve_s",
        ],
        errs,
        path,
    );
}

/// Fault-injection schema: per-lane goodput + tail latency + the fault
/// ledger, a fault-free baseline lane, and the headline invariant that
/// no lane let a request error out.
fn check_faults(path: &str, v: &Json, results: &[Json], errs: &mut Vec<String>) {
    let mut has_baseline = false;
    let mut has_faulted = false;
    for (i, rec) in results.iter().enumerate() {
        let ctx = format!("{path}: results[{i}]");
        for key in [
            "fault_rate",
            "requests",
            "served",
            "errors",
            "injected",
            "faults_fired",
            "fallbacks",
            "quarantined",
            "quarantine_skips",
            "deadline_expired",
            "goodput_per_s",
            "fallback_rate",
            "p50_s",
            "p99_s",
            "p999_s",
        ] {
            check_num(rec, key, errs, &ctx);
        }
        if let Some(errors) = rec.get("errors").and_then(|e| e.as_f64()) {
            if errors != 0.0 {
                errs.push(format!(
                    "{ctx}: {errors} requests errored out — graceful degradation failed"
                ));
            }
        }
        match rec.get("fault_rate").and_then(|r| r.as_f64()) {
            Some(r) if r == 0.0 => has_baseline = true,
            Some(r) if r > 0.0 => has_faulted = true,
            _ => {}
        }
    }
    if !has_baseline {
        errs.push(format!("{path}: missing a fault-free baseline lane"));
    }
    if !has_faulted {
        errs.push(format!("{path}: missing injected-fault lanes"));
    }
    for key in ["patterns", "zipf_s", "trace_len", "baseline_p999_s"] {
        check_num(v, key, errs, path);
    }
}

fn check_file(path: &str) -> Vec<String> {
    let mut errs = Vec::new();
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => return vec![format!("{path}: unreadable: {e}")],
    };
    let v = match json::parse(&text) {
        Ok(v) => v,
        Err(e) => return vec![format!("{path}: invalid JSON: {e}")],
    };
    let Some(results) = v.get("results").and_then(|r| r.as_arr()) else {
        return vec![format!("{path}: missing `results` array")];
    };
    if results.is_empty() {
        errs.push(format!("{path}: empty `results`"));
    }

    // per-bench dispatch: each artifact is gated by its own schema only
    match v.get("bench").and_then(|b| b.as_str()) {
        Some("bench_solver") => check_solver(path, &v, results, &mut errs),
        Some("bench_serving") => check_serving(path, &v, results, &mut errs),
        Some("bench_router") => check_router(path, &v, results, &mut errs),
        Some("bench_online") => check_online(path, &v, results, &mut errs),
        Some("bench_replan") => check_replan(path, &v, results, &mut errs),
        Some("bench_faults") => check_faults(path, &v, results, &mut errs),
        _ => {
            // untagged/other artifacts: the generic record contract
            for (i, rec) in results.iter().enumerate() {
                if rec.get("name").and_then(|n| n.as_str()).is_none() {
                    errs.push(format!("{path}: results[{i}]: missing string `name`"));
                }
            }
        }
    }
    errs
}

fn main() {
    let paths: Vec<String> = std::env::args().skip(1).collect();
    if paths.is_empty() {
        eprintln!("usage: check_bench <BENCH_*.json> ...");
        std::process::exit(2);
    }
    let mut failed = false;
    for path in &paths {
        let errs = check_file(path);
        if errs.is_empty() {
            println!("{path}: ok");
        } else {
            failed = true;
            for e in &errs {
                eprintln!("{e}");
            }
        }
    }
    if failed {
        std::process::exit(1);
    }
}
